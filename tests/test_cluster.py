"""repro.cluster: sharded scatter-gather serving must be EXACT (equal to
single-tier matching for every query, at every shard/replica count, across
rolling swaps), the batched clause classifier must equal the per-query ψ,
and the load generator must be deterministic."""
import numpy as np
import pytest

from repro import cluster
from repro.core import SOLVERS, bitset
from repro.core.tiering import ClauseTiering
from repro.serve import matching

from tests.hypothesis_compat import given, settings, st


def _pipe_parts(tiny_data, tiny_problem, budget_frac=0.5, solver="optpes"):
    r = SOLVERS[solver](tiny_problem, int(tiny_data.n_docs * budget_frac))
    tiering = ClauseTiering.from_selection(tiny_data, r.selected)
    return tiering


def _fleet(tiny_data, tiering, **kw):
    return cluster.TieredCluster(tiny_data.postings, tiering,
                                 tiny_data.n_docs, **kw)


# -- shard planning -----------------------------------------------------------

@pytest.mark.parametrize("n_docs,n_shards", [(200, 1), (200, 2), (200, 4),
                                             (33, 4), (31, 3), (1, 2)])
def test_plan_shards_partitions_word_aligned(n_docs, n_shards):
    shards = cluster.plan_shards(n_docs, n_shards)
    words = bitset.n_words(n_docs)
    assert len(shards) == min(n_shards, words)
    assert shards[0].word_lo == 0
    assert shards[-1].word_hi == words
    for a, b in zip(shards, shards[1:]):
        assert a.word_hi == b.word_lo          # contiguous, no overlap
    assert sum(s.n_docs for s in shards) == n_docs
    for s in shards:
        assert s.doc_lo == s.word_lo * 32
        assert s.n_words >= 1


def test_shard_postings_slices_recompose(tiny_data):
    shards, slices = cluster.shard_postings(tiny_data.postings,
                                            tiny_data.n_docs, 4)
    np.testing.assert_array_equal(np.concatenate(slices, axis=1),
                                  tiny_data.postings)


def test_shard_tier_postings_mask_matches_global(tiny_data, tiny_problem):
    tiering = _pipe_parts(tiny_data, tiny_problem)
    shards, slices = cluster.shard_postings(tiny_data.postings,
                                            tiny_data.n_docs, 4)
    global_t1 = matching.tier_postings(tiny_data.postings, tiering.tier1_docs)
    parts = [cluster.shard_tier_postings(slices[s.index], s,
                                         tiering.tier1_docs)[0]
             for s in shards]
    np.testing.assert_array_equal(np.concatenate(parts, axis=1), global_t1)


# -- batched ψ^clause == per-query ψ^clause -----------------------------------

def test_engine_batch_classifier_equals_per_query_psi(tiny_data, tiny_problem):
    """The kernel-backed serving classifier must agree with the host
    per-query ψ^clause reference on the full query log."""
    tiering = _pipe_parts(tiny_data, tiny_problem)
    want = tiering.classify_queries(tiny_data.log.query_bits)
    got = matching.classify_batch(tiering.clause_vocab_bits,
                                  tiny_data.log.queries,
                                  tiering.vocab_size)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), vocab=st.integers(1, 150),
       n_queries=st.integers(1, 80), n_clauses=st.integers(0, 40))
def test_batched_classifier_property(seed, vocab, n_queries, n_clauses):
    """Random logs: batched kernel classification == per-query subset test."""
    rng = np.random.default_rng(seed)
    qbits = rng.random((n_queries, vocab)) < 0.25
    cbits = rng.random((n_clauses, vocab)) < 0.08
    queries = [tuple(np.nonzero(row)[0]) for row in qbits]
    clauses = [tuple(np.nonzero(row)[0]) for row in cbits]
    tiering = ClauseTiering(clauses=clauses,
                            clause_vocab_bits=bitset.np_pack(cbits),
                            tier1_docs=np.zeros(1, bool), vocab_size=vocab)
    want = tiering.classify_queries(bitset.np_pack(qbits))
    got = matching.classify_batch(tiering.clause_vocab_bits, queries, vocab)
    np.testing.assert_array_equal(got, want)
    # brute force, independent of both implementations
    brute = np.array([any(set(c) <= set(q) for c in clauses) if clauses
                      else False for q in queries])
    np.testing.assert_array_equal(got, brute)


# -- exhaustive cluster-vs-oracle exactness -----------------------------------

@pytest.mark.parametrize("n_shards", [1, 2, 4])
@pytest.mark.parametrize("replicas", [1, 2, 4])
def test_cluster_equals_single_tier_for_every_query(tiny_data, tiny_problem,
                                                    n_shards, replicas):
    """OR-merged sharded scatter-gather == single-tier matching, for EVERY
    query in the log, at every shard/replica count."""
    tiering = _pipe_parts(tiny_data, tiny_problem)
    fleet = _fleet(tiny_data, tiering, n_shards=n_shards,
                   t1_replicas=replicas, t2_replicas=replicas)
    queries = tiny_data.log.queries
    got = []
    for s in range(0, len(queries), 128):
        got.extend(fleet.serve(queries[s:s + 128]))
    want = fleet.serve_reference(queries)
    for q, a, b in zip(queries, got, want):
        np.testing.assert_array_equal(a, b, err_msg=str(q))
    assert fleet.consistency_ok()
    s = fleet.stats
    assert s.n_queries == len(queries)
    if n_shards > 1:
        # both tiers scanned; tier-2 traffic == untiered traffic per query
        assert 0 < s.n_tier1 < s.n_queries
        assert s.cost_saving > 0.0


def test_cluster_stats_match_single_engine(tiny_data, tiny_problem):
    """A 1-shard 1-replica cluster is cost-accounting-identical to the
    single TieredEngine on the same traffic."""
    from repro.serve.engine import TieredEngine
    tiering = _pipe_parts(tiny_data, tiny_problem)
    engine = TieredEngine(tiny_data.postings, tiering, tiny_data.n_docs)
    fleet = _fleet(tiny_data, tiering, n_shards=1, t1_replicas=1)
    queries = tiny_data.log.queries[:256]
    engine.serve(queries)
    fleet.serve(queries)
    assert fleet.stats.n_tier1 == engine.stats.n_tier1
    assert fleet.stats.tier1_words == engine.stats.tier1_words
    assert fleet.stats.tier2_words == engine.stats.tier2_words
    assert fleet.stats.full_words_per_query == \
        engine.stats.full_words_per_query


# -- rolling swaps ------------------------------------------------------------

def test_rolling_swap_exact_and_unmixed_mid_run(tiny_data, tiny_problem):
    """Serving stays oracle-equal on every batch across a rolling swap, the
    fleet is genuinely mixed-generation mid-roll, and no batch ever pairs a
    ψ with a different Tier-1 generation."""
    t_old = _pipe_parts(tiny_data, tiny_problem, budget_frac=0.5)
    t_new = _pipe_parts(tiny_data, tiny_problem, budget_frac=0.25)
    fleet = _fleet(tiny_data, t_old, n_shards=2, t1_replicas=2)
    queries = tiny_data.log.queries

    def assert_batch(lo, hi):
        batch = queries[lo:hi]
        got = fleet.serve(batch)
        want = fleet.serve_reference(batch)
        for a, b in zip(got, want):
            np.testing.assert_array_equal(a, b)

    assert_batch(0, 64)
    gen = fleet.swap_tiering(t_new)
    assert gen == 1
    saw_mixed_fleet = False
    batches = 0
    while fleet.router.rollout is not None and batches < 64:
        assert_batch(64 * (batches % 5), 64 * (batches % 5) + 64)
        saw_mixed_fleet |= len(fleet.router.live_generations()) > 1
        batches += 1
    assert fleet.router.rollout is None, "rollout never completed"
    assert saw_mixed_fleet, "swap was not rolling (no mixed-generation fleet)"
    assert fleet.router.live_generations() == {1}
    assert_batch(0, 64)
    assert fleet.consistency_ok()
    # ψ generation always matched every Tier-1 server's generation
    for t in fleet.trace:
        assert all(g == t.psi_generation for g in t.t1_generations)


def test_single_replica_rollout_falls_back_to_tier2(tiny_data, tiny_problem):
    """With 1 replica per shard there is a mid-roll gap with no complete
    Tier-1 generation: eligible traffic must be served (exactly) by Tier 2,
    never by a mixed pair."""
    t_old = _pipe_parts(tiny_data, tiny_problem, budget_frac=0.5)
    t_new = _pipe_parts(tiny_data, tiny_problem, budget_frac=0.25)
    fleet = _fleet(tiny_data, t_old, n_shards=2, t1_replicas=1)
    queries = tiny_data.log.queries
    fleet.serve(queries[:64])
    fleet.swap_tiering(t_new)
    fallback_batches = 0
    batches = 0
    while fleet.router.rollout is not None and batches < 64:
        got = fleet.serve(queries[:64])
        want = fleet.serve_reference(queries[:64])
        for a, b in zip(got, want):
            np.testing.assert_array_equal(a, b)
        fallback_batches += fleet.trace[-1].psi_generation == -1
        batches += 1
    assert fallback_batches > 0, "expected a Tier-2 fallback window"
    assert fleet.consistency_ok()
    # after the roll, Tier-1 serving resumes on the new generation
    fleet.serve(queries[:64])
    assert fleet.trace[-1].psi_generation == 1
    assert fleet.trace[-1].n_tier1 > 0


def test_controller_drives_cluster_with_rolling_swaps(tiny_data):
    """stream.RetieringController re-tiers a whole cluster through the
    engine-compatible surface; parity holds after every swap."""
    from repro import api, stream
    pipe = api.TieringPipeline.from_data(tiny_data).solve(
        "greedy", budget_frac=0.5)
    fleet = pipe.deploy_cluster(n_shards=2, t1_replicas=2)
    report = stream.run_stream(pipe, scenario="rotate", n_windows=5,
                               queries_per_window=128, seed=0,
                               engine=fleet, verify_swaps=True)
    assert report.n_refits > 0, "scenario should trigger at least one refit"
    assert report.n_parity_checks > 0 and report.parity_all_ok()
    assert fleet.consistency_ok()
    assert fleet.generation == report.windows[-1].generation


# -- load generator -----------------------------------------------------------

def test_loadgen_deterministic_and_sane(tiny_data, tiny_problem):
    tiering = _pipe_parts(tiny_data, tiny_problem)
    fleet = _fleet(tiny_data, tiering, n_shards=2, t1_replicas=2)
    plan = cluster.ClusterPlan.of_cluster(fleet)
    elig = fleet.classify(tiny_data.log.queries[:256])
    a = cluster.run_loadgen(plan, elig, n_queries=1500, seed=7)
    b = cluster.run_loadgen(plan, elig, n_queries=1500, seed=7)
    assert a == b                                  # bit-identical rerun
    assert a.p50_ms <= a.p95_ms <= a.p99_ms <= a.max_ms
    assert a.throughput_qps > 0 and a.fleet_words > 0
    assert 0.0 < a.tier1_fraction < 1.0
    c = cluster.run_loadgen(plan, elig, n_queries=1500, seed=8)
    assert c != a                                  # seed actually threads


def test_loadgen_strong_scaling_per_shard_words(tiny_data, tiny_problem):
    """Per-shard Tier-2 words-scanned decreases with shard count."""
    tiering = _pipe_parts(tiny_data, tiny_problem)
    elig = None
    per_shard = []
    for n_shards in (1, 2, 4):
        fleet = _fleet(tiny_data, tiering, n_shards=n_shards, t1_replicas=1)
        if elig is None:
            elig = fleet.classify(tiny_data.log.queries[:256])
        plan = cluster.ClusterPlan.of_cluster(fleet)
        rep = cluster.run_loadgen(plan, elig, n_queries=1000, seed=0)
        per_shard.append(max(rep.per_shard_t2_words))
    assert per_shard[0] > per_shard[1] > per_shard[2]


def test_loadgen_rollout_outage_falls_back(tiny_data, tiny_problem):
    """A simulated rolling swap on a 1-replica fleet pushes eligible traffic
    to Tier 2 during the outage windows."""
    tiering = _pipe_parts(tiny_data, tiny_problem)
    fleet = _fleet(tiny_data, tiering, n_shards=2, t1_replicas=1)
    plan = cluster.ClusterPlan.of_cluster(fleet)
    elig = np.ones(64, bool)                       # all-eligible traffic
    quiet = cluster.run_loadgen(plan, elig, n_queries=2000, seed=0,
                                rate_qps=50000.0)
    rolled = cluster.run_loadgen(plan, elig, n_queries=2000, seed=0,
                                 rate_qps=50000.0, rollout_at_s=0.01,
                                 swap_ms=5.0)
    assert quiet.t2_fallback_queries == 0
    assert rolled.t2_fallback_queries > 0
    assert rolled.fleet_words > quiet.fleet_words  # fallback scans more


# -- shard-aware budgets ------------------------------------------------------

@pytest.mark.parametrize("n_shards", [2, 4])
def test_cluster_per_shard_budgets_exact_and_capped(tiny_data, n_shards):
    """Exhaustive cluster-vs-oracle with per-shard budgets: every served
    match set equals single-tier matching AND each shard's local Tier-1
    doc count respects its cap B_k."""
    from repro import api
    pipe = api.TieringPipeline.from_data(tiny_data).solve(
        "greedy", budget_frac=0.5, budget_split="traffic", n_shards=n_shards)
    fleet = pipe.deploy_cluster(t1_replicas=2)       # shards == partitions
    assert len(fleet.shards) == n_shards
    queries = tiny_data.log.queries
    got = []
    for s in range(0, len(queries), 128):
        got.extend(fleet.serve(queries[s:s + 128]))
    want = fleet.serve_reference(queries)
    for q, a, b in zip(queries, got, want):
        np.testing.assert_array_equal(a, b, err_msg=str(q))
    assert fleet.consistency_ok()
    caps = pipe.result.extra["caps"]
    t1 = pipe.tiering().tier1_docs
    buf = fleet.router._buffers[fleet.generation]
    for s, cap in zip(fleet.shards, caps):
        local = int(t1[s.doc_lo:s.doc_lo + s.n_docs].sum())
        assert local <= cap, f"shard {s.index}: {local} > B_k={cap}"
        # the fleet's compacted sub-index width reflects the same count
        assert buf.shard_words[s.index] == \
            (bitset.n_words(local) if local else 0)


def test_scoped_rollout_leaves_untouched_shards_alone(tiny_data, tiny_problem):
    """A re-tiering confined to one shard rolls ONLY that shard's replicas:
    untouched shards carry their content metadata-only (no drain, no
    install), serving stays oracle-exact on every mid-roll batch, and no
    batch pairs a ψ with foreign Tier-1 content."""
    data = tiny_data
    tiering = _pipe_parts(data, tiny_problem, solver="greedy")
    fleet = _fleet(data, tiering, n_shards=2, t1_replicas=2)
    s1 = fleet.shards[1]
    # drop a selected clause whose doc coverage lives entirely in shard 1
    # and whose removal keeps shard 0's local D1 slice intact
    sel = np.zeros(len(data.clauses), bool)
    sel[[data.clauses.index(c) for c in tiering.clauses]] = True
    t_new = None
    for j in np.nonzero(sel)[0]:
        row = data.clause_doc_bits[j]
        if bitset.np_popcount(row[:s1.word_lo]) == 0 and \
                bitset.np_popcount(row) > 0:
            trial = sel.copy()
            trial[j] = False
            cand = ClauseTiering.from_selection(data, trial)
            if np.array_equal(cand.tier1_docs[:s1.doc_lo],
                              tiering.tier1_docs[:s1.doc_lo]) and \
                    not np.array_equal(cand.tier1_docs, tiering.tier1_docs):
                t_new = cand
                break
    assert t_new is not None, "no shard-1-confined clause in this selection"

    queries = data.log.queries
    fleet.serve(queries[:64])
    installs0 = [r.n_installs for g in fleet.router.t1 for r in g]
    fleet.swap_tiering(t_new)
    batches = 0
    while fleet.router.rollout is not None and batches < 30:
        lo = 64 * (batches % 4)
        got = fleet.serve(queries[lo:lo + 64])
        want = fleet.serve_reference(queries[lo:lo + 64])
        for a, b in zip(got, want):
            np.testing.assert_array_equal(a, b)
        # 2 replicas on the one changed shard: never a Tier-2 fallback gap
        assert fleet.trace[-1].psi_generation != -1
        batches += 1
    installs1 = [r.n_installs for g in fleet.router.t1 for r in g]
    delta = [a - b for a, b in zip(installs1, installs0)]
    assert delta[:2] == [0, 0], "untouched shard replicas re-installed"
    assert delta[2:] == [1, 1], "changed shard replicas must install once"
    assert fleet.consistency_ok()
    assert fleet.router.live_generations() == {1}


def test_full_swap_still_rolls_every_replica(tiny_data, tiny_problem):
    """When every shard's D1 changes, the content carry must NOT kick in —
    the swap walks all replicas exactly as before."""
    t_old = _pipe_parts(tiny_data, tiny_problem, budget_frac=0.5)
    t_new = _pipe_parts(tiny_data, tiny_problem, budget_frac=0.25)
    fleet = _fleet(tiny_data, t_old, n_shards=2, t1_replicas=2)
    fleet.swap_tiering(t_new)
    assert fleet.router.rollout.n_carried == 0
    n = fleet.router.rollout.run_to_completion()
    assert n == 4


# -- replica autoscaling ------------------------------------------------------

def test_suggest_replicas_saturating_workload(tiny_data, tiny_problem):
    """On an offered load that saturates a 1x fleet, the autoscaler must
    grow the replica groups until the p95 SLO holds — deterministically."""
    tiering = _pipe_parts(tiny_data, tiny_problem)
    fleet = _fleet(tiny_data, tiering, n_shards=2, t1_replicas=1,
                   t2_replicas=1)
    plan = cluster.ClusterPlan.of_cluster(fleet)
    elig = fleet.classify(tiny_data.log.queries[:256])
    base = cluster.run_loadgen(plan, elig, rate_qps=60000.0, n_queries=2000,
                               seed=0)
    slo = base.p95_ms / 4.0          # unreachable without scaling out
    sug = cluster.suggest_replicas(plan, 60000.0, slo, eligible=elig,
                                   n_queries=2000, seed=0)
    assert sug.meets_slo
    assert sug.report.p95_ms <= slo
    assert sug.t1_replicas + sug.t2_replicas > 2
    # deterministic: same inputs, same sizing
    sug2 = cluster.suggest_replicas(plan, 60000.0, slo, eligible=elig,
                                    n_queries=2000, seed=0)
    assert (sug.t1_replicas, sug.t2_replicas) == \
        (sug2.t1_replicas, sug2.t2_replicas)
    assert sug.report == sug2.report


def test_fit_service_model_recovers_linear_law(rng):
    words = np.asarray([16, 64, 256, 1024, 4096], np.float64)
    t_fixed, t_word = 18.0, 3.5
    us = t_fixed + words * t_word + rng.normal(0, 0.01, size=words.shape)
    fit = cluster.fit_service_model(words, us)
    assert fit["t_fixed_us"] == pytest.approx(t_fixed, abs=0.1)
    assert fit["t_word_us"] == pytest.approx(t_word, rel=1e-3)
    assert fit["r2"] > 0.9999


# -- facade -------------------------------------------------------------------

def test_deploy_cluster_facade(tiny_data):
    from repro import api
    pipe = api.TieringPipeline.from_data(tiny_data).solve(
        "greedy", budget_frac=0.5)
    fleet = pipe.deploy_cluster(n_shards=4, t1_replicas=2, t2_replicas=2)
    assert len(fleet.shards) == 4
    got = fleet.serve(tiny_data.log.queries[:32])
    want = fleet.serve_reference(tiny_data.log.queries[:32])
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a, b)
