"""Per-architecture smoke tests (deliverable f): a REDUCED config of each
assigned arch runs one forward/train step on CPU — output shapes asserted,
no NaNs. The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.distributed.compression import CompressionConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import make_train_step

ARCH_NAMES = list(R.all_archs().keys())


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_step(name):
    arch = R.get_arch(name)
    cfg, batch, kind = arch.smoke()

    if kind == "solve":
        from repro.configs.tiering_scsk import solve_fn
        covered_q, covered_d, selected, j = jax.jit(
            solve_fn("solve_dense_m"))(batch)
        assert covered_q.shape == batch["covered_q"].shape
        assert bool(selected[j])
        return

    loss_fn = arch.loss_fn(cfg)
    init_state, train_step = make_train_step(
        loss_fn, OptimizerConfig(name=arch.optimizer, lr=1e-3,
                                 warmup_steps=1),
        compression=CompressionConfig())
    rng = jax.random.key(0)
    if arch.family == "lm":
        from repro.models import transformer as T
        params = T.init_params(rng, cfg)
    elif arch.family == "gnn":
        from repro.models import egnn as G
        params = G.init_params(rng, cfg)
    else:
        from repro.models import recsys as M
        init = {"deepfm": M.deepfm_init, "bst": M.bst_init,
                "bert4rec": M.bert4rec_init,
                "two-tower-retrieval": M.twotower_init}[name]
        params = init(rng, cfg)

    state = init_state(params)
    step = jax.jit(train_step)
    losses = []
    for _ in range(3):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses), (name, losses)
    assert int(state["step"]) == 3
    # optimizer actually moves the params
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda p0, p1: bool(jnp.any(p0 != p1)),
                     params, state["params"]))
    assert moved, name


@pytest.mark.parametrize("name", [n for n in ARCH_NAMES
                                  if R.get_arch(n).family == "lm"])
def test_lm_smoke_decode(name):
    """Reduced-config decode path: one serve_step with a KV cache."""
    from repro.models import transformer as T
    arch = R.get_arch(name)
    cfg, batch, _ = arch.smoke()
    params = T.init_params(jax.random.key(0), cfg)
    cache = T.init_cache(cfg, 2, 16)
    logits, cache = jax.jit(
        lambda p, c, t, l: T.decode_step(p, c, t, l, cfg))(
            params, cache, batch["tokens"][:, :1], jnp.int32(0))
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_all_assigned_archs_registered():
    names = set(R.all_archs())
    expected = {"kimi-k2-1t-a32b", "llama4-maverick-400b-a17b", "gemma2-2b",
                "gemma3-12b", "internlm2-1.8b", "egnn", "bert4rec", "bst",
                "deepfm", "two-tower-retrieval", "tiering-scsk"}
    assert expected <= names


def test_cell_definitions_cover_40_assigned():
    """5 LM x 4 + 1 gnn x 4 + 4 recsys x 4 = 40 assigned cells; skips only
    where the spec allows (long_500k for pure-full-attention archs)."""
    total, skipped = 0, 0
    extras = {"retrieval_cand_tiered"}   # paper-technique variant (extra)
    for name, arch in R.all_archs().items():
        if arch.family == "tiering":
            continue
        for shape in arch.shapes:
            if shape in extras:
                continue
            total += 1
            if shape in arch.skips:
                skipped += 1
                assert shape == "long_500k", (name, shape)
    assert total == 40
    assert skipped == 3  # kimi, llama4, internlm2
