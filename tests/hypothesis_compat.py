"""Optional-dependency shim: property tests skip when hypothesis is absent.

`hypothesis` is a dev extra (see pyproject.toml), not a runtime dep. Test
modules import `given`/`settings`/`st` from here; with hypothesis installed
this is a pass-through, without it the decorated tests collect as skips
instead of breaking collection for the whole tier-1 suite.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed (dev extra)")

    def settings(*a, **k):
        return lambda fn: fn
