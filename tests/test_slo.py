"""repro.obs.slo: rule validation, the metric-spec mini-language (gauge /
delta / percentile / ratio, label filters, windowed histogram deltas), the
multi-window burn-rate state machine with recovery hysteresis, `when`
guards, and the default fleet rule set."""
import pytest

from repro import obs
from repro.obs.slo import SLORule, _parse_target, default_slo_rules


@pytest.fixture(autouse=True)
def _clean_obs():
    prev_on = obs.set_enabled(True)
    prev_ex = obs.set_exporter(None)
    obs.SLO.set_rules([])
    obs.reset()
    yield
    obs.reset()
    obs.SLO.set_rules([])
    obs.set_exporter(prev_ex)
    obs.set_enabled(prev_on)


# -- rule & spec validation ----------------------------------------------------

def test_rule_requires_a_bound_and_sane_windows():
    with pytest.raises(ValueError, match="max= or min="):
        SLORule("r", "gauge:x")
    with pytest.raises(ValueError, match="fast_windows"):
        SLORule("r", "gauge:x", max=1.0, fast_windows=3, slow_windows=2)
    with pytest.raises(ValueError, match="fast_windows"):
        SLORule("r", "gauge:x", max=1.0, fast_windows=0)


def test_target_parsing_and_bad_specs():
    assert _parse_target("name") == ("name", {})
    assert _parse_target("admission_total{decision=reject, tier=t1}") == \
        ("admission_total", {"decision": "reject", "tier": "t1"})
    with pytest.raises(ValueError, match="bad SLO metric target"):
        _parse_target("1bad{")
    with pytest.raises(ValueError, match="label filter"):
        _parse_target("name{oops}")
    obs.SLO.set_rules([SLORule("r", "nonsense:x", max=1.0)])
    with pytest.raises(ValueError, match="unknown SLO metric spec kind"):
        obs.SLO.evaluate(0)
    obs.SLO.set_rules([SLORule("r", "no_kind_separator", max=1.0)])
    with pytest.raises(ValueError, match="want KIND"):
        obs.SLO.evaluate(0)
    obs.SLO.set_rules([SLORule("r", "p150:h", max=1.0)])
    with pytest.raises(ValueError, match=r"p\(0,100\]"):
        obs.SLO.evaluate(0)


# -- spec evaluation -----------------------------------------------------------

def test_gauge_spec_with_label_filter():
    g = obs.gauge("t_slo_g", labels=("arm",))
    g.set(10.0, arm="a")
    g.set(30.0, arm="b")
    obs.SLO.set_rules([SLORule("all", "gauge:t_slo_g", max=100.0),
                       SLORule("only_b", "gauge:t_slo_g{arm=b}", max=100.0)])
    out = obs.SLO.evaluate(0)
    assert out["rules"]["all"]["value"] == pytest.approx(20.0)   # mean
    assert out["rules"]["only_b"]["value"] == pytest.approx(30.0)
    # a gauge never written (or a name of the wrong kind) is N/A, not bad
    obs.SLO.set_rules([SLORule("ghost", "gauge:t_slo_missing", max=1.0)])
    out = obs.SLO.evaluate(1)
    assert out["rules"]["ghost"]["value"] is None
    assert out["rules"]["ghost"]["bad"] is None


def test_delta_spec_is_windowed():
    c = obs.counter("t_slo_c")
    obs.SLO.set_rules([SLORule("d", "delta:t_slo_c", max=10.0)])
    c.inc(4)
    assert obs.SLO.evaluate(0)["rules"]["d"]["value"] == 4.0
    assert obs.SLO.evaluate(1)["rules"]["d"]["value"] == 0.0   # no new incs
    c.inc(25)
    out = obs.SLO.evaluate(2)["rules"]["d"]
    assert out["value"] == 25.0 and out["bad"] is True


def test_ratio_spec_none_while_denominator_flat():
    num = obs.counter("t_slo_num", labels=("decision",))
    obs.SLO.set_rules([SLORule(
        "rej", "ratio:t_slo_num{decision=reject}/t_slo_num", max=0.5)])
    out = obs.SLO.evaluate(0)["rules"]["rej"]
    assert out["value"] is None and out["bad"] is None
    num.inc(3, decision="reject")
    num.inc(1, decision="accept")
    out = obs.SLO.evaluate(1)["rules"]["rej"]
    assert out["value"] == pytest.approx(0.75) and out["bad"] is True
    num.inc(4, decision="accept")
    out = obs.SLO.evaluate(2)["rules"]["rej"]
    assert out["value"] == pytest.approx(0.0)   # windowed: this delta only


def test_percentile_spec_uses_bucket_deltas():
    h = obs.histogram("t_slo_h", buckets=(1.0, 10.0, 100.0))
    obs.SLO.set_rules([SLORule("p", "p95:t_slo_h", max=50.0)])
    h.observe_many([0.5] * 100)
    out = obs.SLO.evaluate(0)["rules"]["p"]
    assert out["value"] <= 1.0 and out["bad"] is False
    # cumulative histogram, windowed judgment: only the NEW tail counts
    h.observe_many([99.0] * 100)
    out = obs.SLO.evaluate(1)["rules"]["p"]
    assert out["value"] > 50.0 and out["bad"] is True
    # no new observations at all: N/A window, burn history untouched
    out = obs.SLO.evaluate(2)["rules"]["p"]
    assert out["value"] is None and out["bad"] is None


def test_when_guard_skips_inapplicable_windows():
    g = obs.gauge("t_slo_refit_s")
    c = obs.counter("t_slo_refits")
    obs.SLO.set_rules([SLORule("budget", "gauge:t_slo_refit_s", max=10.0,
                               when="delta:t_slo_refits", when_min=1.0)])
    g.set(99.0)                                 # stale breach-level gauge...
    for w in range(4):
        out = obs.SLO.evaluate(w)["rules"]["budget"]
        assert out["bad"] is None and out["breached"] is False
    c.inc()                                     # ...until a refit happens
    out = obs.SLO.evaluate(4)["rules"]["budget"]
    assert out["bad"] is True


# -- burn-rate state machine ---------------------------------------------------

def test_burn_rate_needs_both_windows_and_recovery_hysteresis():
    g = obs.gauge("t_slo_v")
    obs.SLO.set_rules([SLORule("r", "gauge:t_slo_v", max=10.0,
                               fast_windows=2, slow_windows=4,
                               fast_burn=1.0, slow_burn=0.5,
                               clear_windows=2)])

    def step(w, value):
        g.set(value)
        return obs.SLO.evaluate(w)["rules"]["r"]

    assert step(0, 0.0)["breached"] is False
    # one bad window: fast burn is only 1/2 — a blip never pages
    assert step(1, 99.0)["breached"] is False
    assert obs.REGISTRY.total("slo_breaches_total") == 0
    # second consecutive bad: fast=2/2, slow=2/3 >= 0.5 — breach
    s = step(2, 99.0)
    assert s["breached"] is True and s["fast_burn"] == 1.0
    assert obs.EVENTS.of_kind("slo_breach")[-1]["rule"] == "r"
    assert obs.REGISTRY.total("slo_breaches_total") == 1
    # one good window is not recovery (clear_windows=2)...
    assert step(3, 0.0)["breached"] is True
    assert not obs.EVENTS.of_kind("slo_recovered")
    # ...two are
    assert step(4, 0.0)["breached"] is False
    assert obs.EVENTS.of_kind("slo_recovered")[-1]["window"] == 4
    # re-breach increments the transition counter again
    step(5, 99.0)
    step(6, 99.0)
    assert obs.REGISTRY.total("slo_breaches_total") == 2
    assert obs.SLO.breached() == ["r"]


def test_segment_and_reset():
    assert obs.SLO.segment() is None            # no rules: no dashboard slot
    g = obs.gauge("t_slo_seg")
    obs.SLO.set_rules([SLORule("a", "gauge:t_slo_seg", max=1.0),
                       SLORule("b", "gauge:t_slo_seg", min=-1.0)])
    assert obs.SLO.segment() == "ok(2)"
    g.set(5.0)
    obs.SLO.evaluate(0)
    assert obs.SLO.segment() == "BREACH(a)"
    obs.SLO.reset()                             # burn state drops...
    assert obs.SLO.segment() == "ok(2)"
    assert len(obs.SLO.rules) == 2              # ...the installed rules stay


def test_default_rules_cover_the_fleet_objectives():
    rules = {r.name: r for r in default_slo_rules()}
    assert {"serve_p95", "serve_p99", "coverage_floor", "t2_fallback_rate",
            "refit_budget", "admission_reject_rate",
            "cache_hit_rate_floor", "shed_ratio_ceiling"} == set(rules)
    assert rules["serve_p95"].metric == "p95:loadgen_latency_ms"
    assert rules["coverage_floor"].min is not None
    assert rules["refit_budget"].when == "delta:refits_total"
    assert rules["cache_hit_rate_floor"].min is not None
    assert rules["shed_ratio_ceiling"].max is not None
    obs.SLO.set_rules(default_slo_rules())
    out = obs.SLO.evaluate(0)                   # cold registry: all N/A...
    assert set(out["rules"]) == set(rules)
    assert out["breached"] == []                # ...and nothing alarms
    # the primed breach counter exports a zero series per rule
    names = {s["labels"]["rule"] for s in
             obs.REGISTRY.get("slo_breaches_total").to_dict()["series"]}
    assert names == set(rules)
