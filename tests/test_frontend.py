"""repro.cluster.frontend: the classify-keyed result cache (LRU/TTL/epoch
sharded store + router integration, bit-identical to `serve_reference`
across rolling tiering AND corpus swaps), hedged dispatch and overload
admission in the loadgen queue model (defaults-off runs pinned bit-identical
to the pre-frontend generator), the Zipf traffic helpers, and — in a
4-fake-device subprocess — cache-on serving mid-rollout on both the host and
fused mesh paths against a cache-off oracle fleet."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import cluster, obs
from repro.cluster import frontend
from repro.core import SOLVERS
from repro.core.tiering import ClauseTiering


def _tiering(data, problem, budget_frac=0.5, solver="greedy"):
    r = SOLVERS[solver](problem, int(data.n_docs * budget_frac))
    return ClauseTiering.from_selection(data, r.selected)


def _fleet(data, tiering, **kw):
    return cluster.TieredCluster(data.postings, tiering, data.n_docs, **kw)


# -- ResultCache store mechanics ----------------------------------------------

def test_cache_validates_capacity_and_shards():
    with pytest.raises(ValueError, match="capacity"):
        frontend.ResultCache(capacity=0)
    with pytest.raises(ValueError, match="n_shards"):
        frontend.ResultCache(n_shards=0)
    # shard count never exceeds capacity (each shard holds >= 1 entry)
    c = frontend.ResultCache(capacity=3, n_shards=8)
    assert c.n_shards == 3


def test_cache_hit_miss_and_stats():
    c = frontend.ResultCache(capacity=8)
    epoch = (0, 0, True)
    row = np.arange(3, dtype=np.uint32)
    assert c.lookup(epoch, b"k") is None
    c.insert(epoch, b"k", True, row)
    elig, got = c.lookup(epoch, b"k")
    assert elig is True
    np.testing.assert_array_equal(got, row)
    # the stored row is a private copy: mutating the source can't corrupt it
    row[0] = 99
    np.testing.assert_array_equal(c.lookup(epoch, b"k")[1], [0, 1, 2])
    s = c.stats
    assert (s.lookups, s.hits, s.misses, s.insertions) == (3, 2, 1, 1)
    assert s.hit_rate == pytest.approx(2 / 3)
    assert len(c) == 1
    snap = c.snapshot()
    assert snap["size"] == 1 and snap["hits"] == 2
    assert c.stats.to_dict()["hit_rate"] == pytest.approx(2 / 3)


def test_cache_lru_evicts_oldest_and_touch_refreshes():
    c = frontend.ResultCache(capacity=2, n_shards=1)
    epoch = (0, 0, True)
    r = np.zeros(1, np.uint32)
    c.insert(epoch, b"a", True, r)
    c.insert(epoch, b"b", True, r)
    assert c.lookup(epoch, b"a") is not None     # touch: a is now newest
    c.insert(epoch, b"c", True, r)               # evicts b, not a
    assert c.lookup(epoch, b"a") is not None
    assert c.lookup(epoch, b"b") is None
    assert c.stats.evictions == 1
    assert len(c) == 2


def test_cache_ttl_expires_entries():
    now = [0.0]
    c = frontend.ResultCache(capacity=8, ttl_s=1.0, clock=lambda: now[0])
    epoch = (0, 0, True)
    c.insert(epoch, b"k", False, np.zeros(1, np.uint32))
    now[0] = 0.9
    assert c.lookup(epoch, b"k") is not None
    now[0] = 1.1
    assert c.lookup(epoch, b"k") is None         # lapsed -> evicted on sight
    assert c.stats.expirations == 1
    assert len(c) == 0


def test_cache_epoch_mismatch_and_invalidate_below():
    c = frontend.ResultCache(capacity=32, n_shards=2)
    r = np.zeros(1, np.uint32)
    c.insert((1, 0, True), b"old", True, r)
    c.insert((2, 1, True), b"new", True, r)
    # a lookup at a moved epoch evicts the stale entry on sight
    assert c.lookup((2, 0, True), b"old") is None
    assert c.stats.invalidations == 1
    # eager sweep: entries below (generation, corpus_version) drop at once
    c.insert((1, 0, True), b"old2", True, r)
    assert c.invalidate_below(2, 1) == 1
    assert c.lookup((2, 1, True), b"new") is not None
    c.clear()
    assert len(c) == 0


def test_cache_keys_spread_over_shards():
    c = frontend.ResultCache(capacity=64, n_shards=8)
    for i in range(64):
        c.insert((0, 0, True), bytes([i, i >> 3]), True,
                 np.zeros(1, np.uint32))
    occupied = sum(1 for d in c._shards if len(d))
    assert occupied >= 4                         # crc32 spreads the keys


# -- AdmissionPolicy ----------------------------------------------------------

def test_admission_policy_parse():
    p = frontend.AdmissionPolicy.parse("0.5,2.0")
    assert (p.queue_bound_ms, p.deadline_ms) == (0.5, 2.0)
    assert p.active
    assert frontend.AdmissionPolicy.parse("1.5").deadline_ms is None
    q = frontend.AdmissionPolicy.parse("-,3")
    assert q.queue_bound_ms is None and q.deadline_ms == 3.0
    assert not frontend.AdmissionPolicy().active
    with pytest.raises(ValueError, match="QUEUE_MS"):
        frontend.AdmissionPolicy.parse("1,2,3")


# -- traffic helpers ----------------------------------------------------------

def test_zipf_keys_seeded_and_skewed():
    a = frontend.zipf_keys(1000, 50, 1.1, seed=3)
    b = frontend.zipf_keys(1000, 50, 1.1, seed=3)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < 50
    # skew concentrates mass on the head ranks
    skewed = (frontend.zipf_keys(4000, 50, 1.5, seed=0) == 0).mean()
    uniform = (frontend.zipf_keys(4000, 50, 0.0, seed=0) == 0).mean()
    assert skewed > 3 * uniform
    with pytest.raises(ValueError, match="n_keys"):
        frontend.zipf_keys(10, 0, 1.0)


def test_keys_of_is_token_set_identity():
    keys = frontend.keys_of([(1, 2), (2, 1), (1, 2, 2), (3,), (1, 2)])
    # order and duplicates don't matter; ids are first-seen dense ints
    assert keys.tolist() == [0, 0, 0, 1, 0]


# -- router integration: hits bit-identical, stats preserved ------------------

def test_router_cache_hits_bit_identical_and_stats(tiny_data, tiny_problem):
    tiering = _tiering(tiny_data, tiny_problem)
    queries = tiny_data.log.queries[:64]
    plain = _fleet(tiny_data, tiering, n_shards=2, t1_replicas=2)
    cached = _fleet(tiny_data, tiering, n_shards=2, t1_replicas=2, cache=True)
    assert plain.cache is None and cached.cache is not None
    a1 = plain.serve(queries)
    b1 = cached.serve(queries)                   # cold: every query misses
    assert cached.cache.stats.hits == 0
    for x, y in zip(a1, b1):
        np.testing.assert_array_equal(x, y)
    words_after_miss = cached.stats.tier1_words + cached.stats.tier2_words
    b2 = cached.serve(queries)                   # warm: every query hits
    ref = cached.serve_reference(queries)
    for x, y in zip(b2, ref):
        np.testing.assert_array_equal(x, y)
    assert cached.cache.stats.hits == len(queries)
    assert cached.stats.cache_hits == len(queries)
    # hits scan ZERO postings words...
    assert cached.stats.tier1_words + cached.stats.tier2_words == \
        words_after_miss
    # ...but keep the traffic-mix metric equal to a cache-off run
    plain.serve(queries)
    assert cached.stats.n_queries == plain.stats.n_queries
    assert cached.stats.tier1_fraction == plain.stats.tier1_fraction
    tr = cached.trace[-1]
    assert tr.n_cached == len(queries)
    assert tr.n_tier1 == 0 and tr.n_tier2 == 0   # no fresh dispatches
    assert cached.consistency_ok()


def test_router_cache_coercion_forms(tiny_data, tiny_problem):
    tiering = _tiering(tiny_data, tiny_problem)
    assert _fleet(tiny_data, tiering, cache=None).cache is None
    assert _fleet(tiny_data, tiering, cache=False).cache is None
    assert _fleet(tiny_data, tiering, cache=64).cache.capacity == 64
    rc = frontend.ResultCache(capacity=7)
    assert _fleet(tiny_data, tiering, cache=rc).cache is rc


def test_router_cache_exact_across_rolling_tiering_swap(tiny_data,
                                                        tiny_problem):
    tiering = _tiering(tiny_data, tiny_problem)
    queries = tiny_data.log.queries[:48]
    fleet = _fleet(tiny_data, tiering, n_shards=2, t1_replicas=2, cache=True)
    fleet.serve(queries)                         # warm at generation 0
    fleet.swap_tiering(_tiering(tiny_data, tiny_problem, budget_frac=0.25))
    batches = 0
    while fleet.router.rollout is not None and batches < 64:
        got = fleet.serve(queries)
        ref = fleet.serve_reference(queries)
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(a, b)
        batches += 1
    assert fleet.router.rollout is None
    assert fleet.consistency_ok()
    # the epoch moved, so the swap forced invalidations AND fresh entries
    assert fleet.cache.stats.invalidations > 0
    got = fleet.serve(queries)                   # post-swap warm pass: hits
    ref = fleet.serve_reference(queries)
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(a, b)
    assert fleet.cache.stats.hits > 0


def test_router_cache_exact_across_rolling_corpus_swap():
    # append_docs mutates TieringData in place: fresh data, never fixtures
    from repro import api, ingest
    from repro.data import incidence, synthetic
    corpus, log = synthetic.make_tiering_dataset(0, "tiny")
    data = incidence.build_tiering_data(corpus, log, min_support=1e-3)
    pipe = api.TieringPipeline.from_data(data).solve("greedy",
                                                     budget_frac=0.5)
    fleet = pipe.deploy_cluster(n_shards=2, t1_replicas=2, cache=True)
    queries = log.queries[:48]
    fleet.serve(queries)
    fleet.serve(queries)
    assert fleet.cache.stats.hits > 0            # warm before the swap
    feed = ingest.DocumentFeed(log=data.log,
                               vocab_size=data.corpus.vocab_size,
                               rate=48.0, seed=7)
    delta = incidence.append_docs(data, list(feed.window(0)))
    pipe.problem = pipe.problem.with_doc_block(delta.clause_cols,
                                               delta.n_docs)
    pipe.adopt_selection(pipe.problem.state_for(
        np.nonzero(np.asarray(pipe.result.selected))[0]))
    fleet.swap_corpus(data.postings, delta.n_docs, pipe.tiering())
    batches = 0
    while fleet.router.rollout is not None and batches < 64:
        got = fleet.serve(queries)
        v = fleet.trace[-1].corpus_version
        ref = fleet.serve_reference(queries, corpus_version=v)
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(a, b)
        batches += 1
    assert fleet.router.rollout is None
    assert fleet.consistency_ok()
    got = fleet.serve(queries)                   # warm at the new version
    ref = fleet.serve_reference(queries)
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(a, b)


def test_frontend_counters_track_cache(tiny_data, tiny_problem):
    tiering = _tiering(tiny_data, tiny_problem)
    queries = tiny_data.log.queries[:32]
    prev_on = obs.set_enabled(True)
    prev_ex = obs.set_exporter(None)
    obs.reset()
    try:
        fleet = _fleet(tiny_data, tiering, cache=True)
        # priming exports zeroed series before any traffic flows
        assert obs.REGISTRY.total("frontend_cache_hits_total") == 0
        fleet.serve(queries)
        fleet.serve(queries)
        s = fleet.cache.stats
        assert obs.REGISTRY.total("frontend_cache_lookups_total") == s.lookups
        assert obs.REGISTRY.total("frontend_cache_hits_total") == s.hits
        assert obs.REGISTRY.total("frontend_cache_misses_total") == s.misses
    finally:
        obs.reset()
        obs.set_exporter(prev_ex)
        obs.set_enabled(prev_on)


# -- loadgen: defaults-off runs pinned bit-identical to the seed generator ----

_PLAN = cluster.ClusterPlan(t1_words=((3, 3), (0, 0), (5, 5)),
                            t2_words=((8, 8), (7, 7), (9, 9)))
_ELIG = np.array([1, 0, 1, 1, 0], bool)


def _pin(rep, **want):
    for k, v in want.items():
        got = getattr(rep, k)
        if isinstance(v, float):
            assert got == pytest.approx(v, rel=1e-12, abs=0.0), (k, got)
        else:
            assert got == v, (k, got)


def test_loadgen_defaults_off_pinned_base():
    rep = cluster.run_loadgen(_PLAN, _ELIG)
    _pin(rep,
         p50_ms=0.04000000000001225,
         p95_ms=0.056000000000000494,
         p99_ms=0.38399999999999546,
         mean_ms=0.053374920729458396,
         max_ms=0.461990155094405,
         fleet_words=57600,
         throughput_qps=19618.68981448589,
         max_t1_util=0.25210016411613734,
         max_t1_backlog_ms=0.09904343179559238)
    # the front-end fields exist and stay zero when every layer is off
    assert (rep.n_hedges, rep.n_hedge_wins, rep.n_hedge_cancels,
            rep.hedge_extra_words, rep.n_shed, rep.n_shed_to_t2,
            rep.n_cache_hits) == (0,) * 7
    assert rep.shed_frac == 0.0 and rep.cache_hit_rate == 0.0


def test_loadgen_defaults_off_pinned_fast_and_rollout():
    fast = cluster.run_loadgen(_PLAN, _ELIG, rate_qps=80000.0,
                               n_queries=1500, seed=3)
    _pin(fast,
         p50_ms=0.31151297096737673,
         p95_ms=1.230360015368779,
         p99_ms=1.3049804630053103,
         mean_ms=0.4536487489924385,
         fleet_words=21600,
         max_t1_util=0.9945387691335608)
    roll = cluster.run_loadgen(_PLAN, _ELIG, rollout_at_s=0.01, swap_ms=2.0)
    _pin(roll,
         mean_ms=0.053453239520822926,
         fleet_words=57600,
         max_t1_util=0.2534734724031512)
    stw = cluster.run_loadgen(_PLAN, _ELIG, rollout_at_s=0.01,
                              rollout_mode="stw", ingest_qps=500.0)
    _pin(stw,
         p95_ms=52.36657698411578,
         p99_ms=58.59492847543175,
         mean_ms=13.45586276623517,
         n_ingest_events=102,
         ingest_words_total=13056,
         stw_delayed_queries=1189)


# -- loadgen: hedged dispatch -------------------------------------------------

def test_hedging_cuts_p99_at_two_replicas():
    base = cluster.run_loadgen(_PLAN, _ELIG)
    hedged = cluster.run_loadgen(_PLAN, _ELIG, hedge_ms=0.1)
    assert hedged.n_hedges > 0
    assert 0 < hedged.n_hedge_wins <= hedged.n_hedges
    assert hedged.n_hedge_cancels == hedged.n_hedges
    assert hedged.hedge_extra_words > 0
    # first-response-wins on straggled legs cuts the modelled tail
    assert hedged.p99_ms < base.p99_ms
    # winner-leg accounting: fleet words equal the unhedged run (the losing
    # leg's partial scan is reported separately, not double-counted)
    assert hedged.fleet_words == base.fleet_words
    assert hedged.n_queries == base.n_queries


def test_hedging_needs_a_second_replica():
    solo = _PLAN.resized(t1_replicas=1, t2_replicas=1)
    base = cluster.run_loadgen(solo, _ELIG)
    hedged = cluster.run_loadgen(solo, _ELIG, hedge_ms=0.1)
    assert hedged.n_hedges == 0
    assert hedged.to_dict() == base.to_dict()    # no candidates: noop


# -- loadgen: overload admission ----------------------------------------------

def test_admission_sheds_under_overload():
    kw = dict(rate_qps=200000.0, n_queries=3000, seed=0)
    unprotected = cluster.run_loadgen(_PLAN, _ELIG, **kw)
    policy = frontend.AdmissionPolicy(queue_bound_ms=0.3, deadline_ms=1.0)
    shed = cluster.run_loadgen(_PLAN, _ELIG, admission=policy, **kw)
    assert shed.n_shed > 0 and shed.n_shed_to_t2 > 0
    assert shed.shed_frac == pytest.approx(
        (shed.n_shed + shed.n_shed_to_t2) / shed.n_queries)
    # shedding keeps the admitted tail flat while unprotected queues collapse
    assert shed.p99_ms < unprotected.p99_ms
    assert shed.fleet_words < unprotected.fleet_words
    line = shed.line()
    assert f"shed={shed.n_shed}+{shed.n_shed_to_t2}->t2" in line


def test_inactive_admission_is_noop():
    base = cluster.run_loadgen(_PLAN, _ELIG)
    noop = cluster.run_loadgen(_PLAN, _ELIG,
                               admission=frontend.AdmissionPolicy())
    assert noop.to_dict() == base.to_dict()


# -- loadgen: result-cache model ----------------------------------------------

def test_loadgen_cache_hits_cut_words_and_tail():
    base = cluster.run_loadgen(_PLAN, _ELIG)
    keys = frontend.zipf_keys(4000, 100, 1.1, seed=0)
    rep = cluster.run_loadgen(_PLAN, _ELIG, cache_keys=keys)
    assert rep.n_cache_hits > 0
    assert rep.cache_hit_rate == pytest.approx(
        rep.n_cache_hits / rep.n_queries)
    assert rep.cache_hit_rate > 0.5              # zipf repeat traffic
    assert rep.fleet_words < base.fleet_words // 2
    assert rep.p99_ms <= base.p99_ms
    assert f"cache_hit={rep.cache_hit_rate:.3f}" in rep.line()
    with pytest.raises(ValueError, match="cache_keys"):
        cluster.run_loadgen(_PLAN, _ELIG, cache_keys=np.empty(0, np.int64))
    with pytest.raises(ValueError, match="cache_capacity"):
        cluster.run_loadgen(_PLAN, _ELIG, cache_keys=keys, cache_capacity=0)


def test_loadgen_obs_counters_and_report_roundtrip():
    prev_on = obs.set_enabled(True)
    prev_ex = obs.set_exporter(None)
    obs.reset()
    try:
        keys = frontend.zipf_keys(4000, 100, 1.1, seed=0)
        rep = cluster.run_loadgen(_PLAN, _ELIG, hedge_ms=0.1,
                                  cache_keys=keys)
        assert obs.REGISTRY.total("loadgen_hedges_total") == rep.n_hedges
    finally:
        obs.reset()
        obs.set_exporter(prev_ex)
        obs.set_enabled(prev_on)
    d = rep.to_dict()
    for k in ("n_hedges", "n_hedge_wins", "n_hedge_cancels",
              "hedge_extra_words", "n_shed", "n_shed_to_t2", "shed_frac",
              "n_cache_hits", "cache_hit_rate"):
        assert k in d
    back = cluster.LoadgenReport.from_dict(d)
    assert back.to_dict() == d


# -- 4-device parity: cache-on serving mid-rollout, host AND mesh -------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["JAX_PLATFORMS"] = "cpu"
import jax, numpy as np
from repro import api, cluster, distributed as D
from repro.core import SOLVERS
from repro.core.tiering import ClauseTiering

assert len(jax.devices()) == 4
pipe = (api.TieringPipeline.from_synthetic(seed=0, scale="tiny")
        .mine(min_support=1e-3).solve("greedy", budget_frac=0.5))
data = pipe.data
queries = pipe.log.queries[:64]
r2 = SOLVERS["greedy"](pipe.problem, int(data.n_docs * 0.25))
t_new = ClauseTiering.from_selection(data, r2.selected)


def full_snap(fleet):
    s = fleet.stats
    return (s.n_queries, s.n_tier1, s.tier1_words, s.tier2_words,
            s.cache_hits,
            [(t.psi_generation, t.n_tier1, t.n_tier2, t.n_cached,
              t.corpus_version) for t in fleet.trace])


def run_pair(mesh):
    def build(cache):
        return cluster.TieredCluster(data.postings, pipe.tiering(),
                                     data.n_docs, n_shards=2, t1_replicas=2,
                                     cache=cache)
    cached, plain = build(True), build(False)
    # pass 1 (cold cache, all-miss): stats and BatchTrace are BIT-IDENTICAL
    a, b = cached.serve(queries), plain.serve(queries)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    assert full_snap(cached) == full_snap(plain)
    # rolling swap with repeat traffic: every batch equal to the oracle
    cached.swap_tiering(t_new)
    plain.swap_tiering(t_new)
    batches = 0
    while (cached.router.rollout is not None
           or plain.router.rollout is not None) and batches < 64:
        a, b = cached.serve(queries), plain.serve(queries)
        ref = cached.serve_reference(queries)
        for x, y, z in zip(a, b, ref):
            np.testing.assert_array_equal(x, y)
            np.testing.assert_array_equal(x, z)
        batches += 1
    assert cached.router.rollout is None and plain.router.rollout is None
    # warm pass at the landed generation: all hits, still oracle-exact
    a = cached.serve(queries)
    for x, z in zip(a, cached.serve_reference(queries)):
        np.testing.assert_array_equal(x, z)
    assert cached.cache.stats.hits > 0
    assert cached.trace[-1].n_cached == len(queries)
    assert cached.stats.n_queries == plain.stats.n_queries + len(queries)
    assert cached.consistency_ok() and plain.consistency_ok()
    assert cached.cache.stats.invalidations > 0
    if mesh:
        assert cached.router._mesh_tables, "fused path never engaged"
    return cached.cache.stats.hit_rate


host_rate = run_pair(mesh=False)
with D.use_mesh(D.shard_mesh()):
    mesh_rate = run_pair(mesh=True)
assert host_rate > 0 and mesh_rate > 0
print("FRONTEND-4DEV-OK")
"""


def test_frontend_cache_parity_4dev():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": os.environ.get(
            "PATH", "/usr/bin:/bin"), "HOME": os.environ.get("HOME", "/root")},
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=900)
    assert "FRONTEND-4DEV-OK" in out.stdout, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
