"""repro.ingest: live corpus growth must stay EXACT — append-only block
appends bit-identical to a from-scratch rebuild, stale pre-append states
rejected by name, mandatory Theorem-3.1 admission of fresh docs, secretary
admission policy mechanics, versioned serving parity through rolling corpus
swaps, and (in a 4-fake-device subprocess) a rolling fleet mid-ingest-rollout
bit-identical to a stop-the-world fleet at the same corpus version."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import cluster, ingest, stream
from repro.core import bitset
from repro.data import incidence, synthetic


def _fresh_data(seed=0, min_support=1e-3):
    # append_docs mutates TieringData in place — never use session fixtures
    corpus, log = synthetic.make_tiering_dataset(seed, "tiny")
    return incidence.build_tiering_data(corpus, log, min_support=min_support)


def _fresh_pipe(data=None, **solve_kw):
    from repro import api
    kw = dict(budget_frac=0.5)
    kw.update(solve_kw)
    return api.TieringPipeline.from_data(
        data if data is not None else _fresh_data()).solve("greedy", **kw)


def _feed_docs(data, t=0, rate=48.0, seed=7):
    feed = ingest.DocumentFeed(log=data.log,
                               vocab_size=data.corpus.vocab_size,
                               rate=rate, seed=seed)
    return list(feed.window(t))


# -- append-only block appends ------------------------------------------------

def test_append_docs_existing_words_never_move():
    data = _fresh_data()
    before = data.postings.copy()
    before_cd = data.clause_doc_bits.copy()
    before_qd = data.query_doc_bits.copy()
    delta = incidence.append_docs(data, _feed_docs(data))
    assert delta.word_lo == before.shape[1]
    np.testing.assert_array_equal(data.postings[:, :delta.word_lo], before)
    np.testing.assert_array_equal(
        data.clause_doc_bits[:, :delta.word_lo], before_cd)
    np.testing.assert_array_equal(
        data.query_doc_bits[:, :delta.word_lo], before_qd)
    assert delta.n_holes == delta.word_lo * 32 - delta.doc_lo
    assert 0 <= delta.n_holes < 32
    assert delta.n_docs == delta.word_lo * 32 + delta.n_new


def test_append_docs_bit_identical_to_scratch_rebuild():
    """The appended incidence must equal a full rebuild over the grown
    corpus — clauses are mined from the (unchanged) log, so every structure
    is directly comparable."""
    data = _fresh_data()
    incidence.append_docs(data, _feed_docs(data))
    scratch = incidence.build_tiering_data(data.corpus, data.log,
                                           min_support=1e-3)
    assert scratch.clauses == data.clauses
    np.testing.assert_array_equal(scratch.postings, data.postings)
    np.testing.assert_array_equal(scratch.clause_doc_bits,
                                  data.clause_doc_bits)
    np.testing.assert_array_equal(scratch.query_doc_bits,
                                  data.query_doc_bits)


def test_append_docs_holes_match_nothing():
    data = _fresh_data()
    delta = incidence.append_docs(data, _feed_docs(data))
    for d in range(delta.doc_lo, delta.word_lo * 32):   # the hole slots
        w, b = d // 32, d % 32
        assert not (data.postings[:, w] >> b & 1).any()
        assert not (data.clause_doc_bits[:, w] >> b & 1).any()
        assert data.corpus.doc_tokens[d] == ()


def test_append_docs_rejects_empty_and_bad_tokens():
    data = _fresh_data()
    with pytest.raises(ValueError, match="at least one"):
        incidence.append_docs(data, [])
    with pytest.raises(ValueError, match="outside vocab"):
        incidence.append_docs(data, [(0, data.corpus.vocab_size)])


# -- stale pre-append states (satellite: with_weights / prune_state) ----------

def test_stale_state_rejected_by_name_and_state_for_rederives():
    """After append + `with_doc_block`, the pre-append SolverState must be
    rejected with the named remedy, and `state_for` must re-derive a working
    warm state over the grown incidence (Theorem 3.1's mandatory leg)."""
    pipe = _fresh_pipe()
    prev_state = pipe.result.state
    delta = incidence.append_docs(pipe.data, _feed_docs(pipe.data))
    problem = pipe.problem.with_doc_block(delta.clause_cols, delta.n_docs)
    pipe.problem = problem
    with pytest.raises(ValueError, match="state_for"):
        stream.check_state_width(problem, prev_state)
    with pytest.raises(ValueError, match="stale SolverState"):
        stream.prune_state(problem, prev_state,
                           weights=np.asarray(pipe.log.train_weights))
    with pytest.raises(ValueError, match="stale warm-start state"):
        pipe.refit(np.asarray(pipe.log.train_weights), state=prev_state)
    # the remedy works: same selection, grown widths, refit accepts it
    state = problem.state_for(np.nonzero(np.asarray(prev_state.selected))[0])
    np.testing.assert_array_equal(np.asarray(state.selected),
                                  np.asarray(prev_state.selected))
    assert int(np.asarray(state.covered_d).shape[0]) == problem.wd
    pipe.adopt_selection(state)
    pipe.refit(np.asarray(pipe.log.train_weights), state=state)


def test_mandatory_admission_covers_appended_docs():
    """Theorem 3.1 through ingest: every appended doc matched by a SELECTED
    clause must land in Tier 1 of the re-derived tiering."""
    pipe = _fresh_pipe()
    delta = incidence.append_docs(pipe.data, _feed_docs(pipe.data))
    problem = pipe.problem.with_doc_block(delta.clause_cols, delta.n_docs)
    pipe.problem = problem
    sel = np.nonzero(np.asarray(pipe.result.selected))[0]
    pipe.adopt_selection(problem.state_for(sel))
    tiering = pipe.tiering()
    matched_block = bitset.np_unpack(
        np.bitwise_or.reduce(delta.clause_cols[sel], axis=0),
        delta.n_docs - delta.word_lo * 32)
    t1_block = tiering.tier1_docs[delta.word_lo * 32:]
    assert matched_block.any(), "feed produced no mandatory admissions"
    assert np.all(t1_block[matched_block]), \
        "a doc matched by a selected clause is missing from Tier 1"


# -- stale corpus versions (satellite: named rollout error) -------------------

def test_swap_with_stale_tiering_raises_named_error():
    pipe = _fresh_pipe()
    fleet = pipe.deploy_cluster(n_shards=2, t1_replicas=1)
    stale = pipe.tiering()                       # pre-append doc count
    delta = incidence.append_docs(pipe.data, _feed_docs(pipe.data))
    pipe.problem = pipe.problem.with_doc_block(delta.clause_cols,
                                               delta.n_docs)
    pipe.adopt_selection(pipe.problem.state_for(
        np.nonzero(np.asarray(pipe.result.selected))[0]))
    fleet.swap_corpus(pipe.data.postings, delta.n_docs, pipe.tiering(),
                      immediate=True)
    with pytest.raises(cluster.StaleCorpusError, match="rebuild it"):
        fleet.swap_tiering(stale)


def test_prepared_buffer_from_old_version_raises_named_error():
    """A buffer prepared BEFORE a corpus swap must not roll out after it."""
    pipe = _fresh_pipe()
    fleet = pipe.deploy_cluster(n_shards=2, t1_replicas=1)
    buf = fleet.prepare_tiering(pipe.tiering())
    delta = incidence.append_docs(pipe.data, _feed_docs(pipe.data))
    pipe.problem = pipe.problem.with_doc_block(delta.clause_cols,
                                               delta.n_docs)
    pipe.adopt_selection(pipe.problem.state_for(
        np.nonzero(np.asarray(pipe.result.selected))[0]))
    fleet.swap_corpus(pipe.data.postings, delta.n_docs, pipe.tiering(),
                      immediate=True)
    with pytest.raises(cluster.StaleCorpusError, match="corpus version"):
        fleet.swap_tiering(buf)


def test_engine_swap_corpus_rejects_shrinking():
    from repro.serve.engine import TieredEngine
    pipe = _fresh_pipe()
    engine = TieredEngine(pipe.data.postings, pipe.tiering(),
                          pipe.data.n_docs)
    with pytest.raises(ValueError, match="append-only"):
        engine.swap_corpus(pipe.data.postings[:, :-1],
                           pipe.data.n_docs - 40, pipe.tiering())


# -- the admission policy -----------------------------------------------------

def test_admission_policy_observe_then_accept():
    policy = ingest.AdmissionPolicy(observe=4, quantile=0.5, window=16)
    assert policy.threshold() == float("inf")
    for i in range(4):                            # observe phase: never admit
        assert not policy.offer(i, ratio=100.0, feasible=True)
    assert all(d.reason == "observe" for d in policy.decisions)
    assert policy.threshold() == 100.0            # trailing quantile is live
    assert not policy.offer(4, ratio=50.0, feasible=True)    # below
    assert policy.decisions[-1].reason == "below"
    assert not policy.offer(5, ratio=200.0, feasible=False)  # gate wins
    assert policy.decisions[-1].reason == "infeasible"
    assert policy.n_infeasible == 1
    assert policy.offer(6, ratio=200.0, feasible=True)       # clears
    assert policy.decisions[-1].reason == "admitted"
    assert policy.n_admitted == 1 and policy.n_offers == 7
    assert "admitted=1" in policy.summary()


def test_admission_policy_trailing_window_and_floor():
    policy = ingest.AdmissionPolicy(observe=2, quantile=0.0, window=4,
                                    min_ratio=10.0)
    for r in (1.0, 2.0, 3.0, 4.0, 5.0, 6.0):
        policy.offer(0, ratio=r, feasible=True)
    # window=4 keeps ratios {3..6}; quantile 0 -> min of window, floored
    assert policy.threshold() == 10.0
    assert not policy.offer(0, ratio=9.0, feasible=True)
    assert policy.offer(0, ratio=10.0, feasible=True)
    with pytest.raises(ValueError, match="quantile"):
        ingest.AdmissionPolicy(quantile=1.5)


# -- the seeded feed ----------------------------------------------------------

def test_document_feed_deterministic_and_in_vocab():
    data = _fresh_data()
    feed_a = ingest.DocumentFeed(log=data.log,
                                 vocab_size=data.corpus.vocab_size,
                                 rate=32.0, seed=3)
    feed_b = ingest.DocumentFeed(log=data.log,
                                 vocab_size=data.corpus.vocab_size,
                                 rate=32.0, seed=3)
    wins_a = [feed_a.window(t) for t in range(4)]
    wins_b = [feed_b.window(t) for t in range(4)]
    assert wins_a == wins_b                       # seed-deterministic A/B
    docs = [d for w in wins_a for d in w]
    assert docs
    for d in docs:
        assert d == tuple(sorted(set(d))) and len(d) >= 1
        assert all(0 <= t < data.corpus.vocab_size for t in d)


# -- end-to-end ingest loops --------------------------------------------------

def test_run_ingest_single_engine_verified():
    rep = ingest.run_ingest(
        _fresh_pipe(budget_split="traffic", n_shards=2),
        scenario="rotate", n_windows=3, queries_per_window=128, seed=0,
        arrivals_per_window=32.0, verify=True)
    assert rep.failed_windows() == 0
    assert rep.n_ingested > 0
    assert rep.windows[-1].corpus_version == len(rep.windows)
    assert all(w.ingest_ok for w in rep.windows)


def test_run_ingest_rolling_fleet_verified():
    pipe = _fresh_pipe(budget_split="traffic", n_shards=2)
    fleet = pipe.deploy_cluster(n_shards=2, t1_replicas=2, t2_replicas=2)
    rep = ingest.run_ingest(
        pipe, engine=fleet, scenario="rotate", n_windows=3,
        queries_per_window=128, seed=0, arrivals_per_window=32.0,
        verify=True)
    assert rep.failed_windows() == 0
    assert fleet.consistency_ok()
    assert fleet.corpus_version == len(rep.windows)
    # every trace entry pinned a consistent (psi, T1, T2) triple
    assert all(t.consistent for t in fleet.trace)
    fleet.drain_rollout()
    sample = pipe.log.queries[:64]
    got = fleet.serve(sample)
    want = fleet.serve_reference(
        sample, corpus_version=fleet.corpus_version)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a, b)


def test_serve_reference_unknown_version_raises():
    pipe = _fresh_pipe()
    fleet = pipe.deploy_cluster(n_shards=2, t1_replicas=1)
    with pytest.raises(KeyError, match="no live buffer"):
        fleet.serve_reference(pipe.log.queries[:4], corpus_version=99)


# -- loadgen: ingest traffic --------------------------------------------------

def _loadgen_plan():
    pipe = _fresh_pipe()
    fleet = pipe.deploy_cluster(n_shards=2, t1_replicas=2, t2_replicas=2)
    return (cluster.ClusterPlan.of_cluster(fleet),
            fleet.classify(pipe.log.queries[:256]))


def test_loadgen_ingest_qps_zero_is_bit_compatible():
    plan, elig = _loadgen_plan()
    base = cluster.run_loadgen(plan, elig, n_queries=800, seed=0)
    zero = cluster.run_loadgen(plan, elig, n_queries=800, seed=0,
                               ingest_qps=0.0)
    assert base == zero                 # same rng draws, same report
    assert base.n_ingest_events == 0 and base.stw_delayed_queries == 0


def test_loadgen_stw_outage_delays_queries():
    plan, elig = _loadgen_plan()
    kw = dict(n_queries=2000, seed=0, rollout_at_s=0.02, swap_ms=5.0,
              ingest_qps=100.0)
    rolling = cluster.run_loadgen(plan, elig, rollout_mode="rolling", **kw)
    stw = cluster.run_loadgen(plan, elig, rollout_mode="stw", **kw)
    assert stw.stw_delayed_queries > 0 and rolling.stw_delayed_queries == 0
    assert stw.p99_ms > rolling.p99_ms  # one fleet-wide stop vs rolling
    assert stw.n_ingest_events == rolling.n_ingest_events > 0
    with pytest.raises(ValueError, match="rollout_mode"):
        cluster.run_loadgen(plan, elig, rollout_mode="bogus")


# -- rolling vs stop-the-world mirror parity, 4 fake devices ------------------

MIRROR_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["JAX_PLATFORMS"] = "cpu"
import jax, numpy as np
from repro import api, distributed as D, ingest
from repro.data import incidence

assert len(jax.devices()) == 4

pipe = (api.TieringPipeline.from_synthetic(seed=0, scale="tiny")
        .mine(min_support=1e-3)
        .solve("greedy", budget_frac=0.5, budget_split="traffic",
               n_shards=2))
queries = pipe.log.queries[:64]
roller = pipe.deploy_cluster(n_shards=2, t1_replicas=2, t2_replicas=2)
mirror = pipe.deploy_cluster(n_shards=2, t1_replicas=2, t2_replicas=2)
feed = ingest.DocumentFeed(log=pipe.log, vocab_size=pipe.corpus.vocab_size,
                           rate=48.0, seed=7)

snaps = {}          # corpus_version -> (postings, n_docs, tiering)
applied = 0         # the mirror fleet's stop-the-world corpus version
mid_rollout = 0     # batches served at an OLDER version than the target

with D.use_mesh(D.shard_mesh()):
    for t in range(3):
        delta = incidence.append_docs(pipe.data, list(feed.window(t)))
        pipe.problem = pipe.problem.with_doc_block(delta.clause_cols,
                                                   delta.n_docs)
        pipe.adopt_selection(pipe.problem.state_for(
            np.nonzero(np.asarray(pipe.result.selected))[0]))
        tiering = pipe.tiering()
        roller.swap_corpus(pipe.data.postings, delta.n_docs, tiering)
        snaps[roller.corpus_version] = (pipe.data.postings.copy(),
                                        delta.n_docs, tiering)
        batches = 0
        while True:
            got = roller.serve(queries)
            served_v = roller.trace[-1].corpus_version
            mid_rollout += served_v < roller.corpus_version
            # the mirror jumps stop-the-world to the version the roller
            # SERVED: both fleets are then at the same corpus version and
            # must be bit-identical
            while applied < served_v:
                applied += 1
                p, n, tg = snaps[applied]
                mirror.swap_corpus(p, n, tg, immediate=True)
            want = mirror.serve(queries)
            for a, b in zip(got, want):
                np.testing.assert_array_equal(a, b)
            ref = roller.serve_reference(queries, corpus_version=served_v)
            for a, b in zip(got, ref):
                np.testing.assert_array_equal(a, b)
            batches += 1
            if roller.router.rollout is None or batches >= 64:
                break
        assert roller.router.rollout is None, "rollout never completed"

assert mid_rollout > 0, "never observed a mid-rollout batch"
assert applied == roller.corpus_version == 3
assert roller.consistency_ok() and mirror.consistency_ok()
assert roller.router._mesh_tables, "fused path never engaged"
print(f"mid_rollout_batches={mid_rollout}")
print("INGEST-MIRROR-OK")
"""


def test_ingest_mirror_parity_4dev():
    """Acceptance: a fleet serving MID-INGEST-ROLLOUT is bit-identical to a
    stop-the-world rebuild at the same corpus version, on a forced 4-device
    mesh (the CI parity configuration)."""
    out = subprocess.run(
        [sys.executable, "-c", MIRROR_SCRIPT], capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": os.environ.get(
            "PATH", "/usr/bin:/bin"), "HOME": os.environ.get("HOME", "/root")},
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=900)
    assert "INGEST-MIRROR-OK" in out.stdout, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
