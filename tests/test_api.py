"""The unified solver API: registry, SolverState warm starts, SolveConfig,
Trace, and the TieringPipeline facade."""
import numpy as np
import pytest

from repro import api


BUDGET_FRAC = 0.5


def _budget(data):
    return data.n_docs * BUDGET_FRAC


# -- registry round-trip ------------------------------------------------------

def test_registry_lists_all_solver_families():
    names = api.list_solvers()
    for required in ("greedy", "lazy", "optpes", "isk1", "isk2", "agnostic",
                     "stochastic", "flow-popularity", "flow-max", "flow-sgd"):
        assert required in names


@pytest.mark.parametrize("name", ["greedy", "lazy", "optpes", "isk1", "isk2",
                                  "agnostic", "stochastic"])
def test_registry_roundtrip_core(tiny_data, tiny_problem, name):
    """Every registered SCSK solver returns a valid SolverResult through the
    ONE uniform signature."""
    budget = _budget(tiny_data)
    r = api.solve(tiny_problem, api.SolveConfig(
        budget=budget, solver=name,
        options={"batch_queries": 512} if name == "stochastic" else {}))
    assert isinstance(r, api.SolverResult)
    assert r.g_final <= budget + 1e-6
    assert r.f_final > 0
    assert r.selected.shape == (tiny_problem.n_clauses,)
    assert len(r.f_history) == len(r.g_history) == len(r.time_history)
    assert r.state is not None
    assert int(r.state.selected.sum()) == int(r.selected.sum())


@pytest.mark.parametrize("name", ["flow-popularity", "flow-max", "flow-sgd"])
def test_registry_roundtrip_flow(tiny_data, name):
    """The flow baselines ride the same registry via their data adapters."""
    budget = tiny_data.n_docs // 2
    opts = {"steps": 60} if name == "flow-sgd" else {}
    r = api.solve(tiny_data, api.SolveConfig(budget=budget, solver=name,
                                             options=opts))
    assert isinstance(r, api.SolverResult)
    assert r.g_final <= budget          # tier-1 doc count
    assert 0.0 <= r.f_final <= 1.0      # train coverage
    assert "flow" in r.extra
    # passing an SCSKProblem without data must fail loudly
    with pytest.raises(ValueError):
        api.solve(object(), api.SolveConfig(budget=budget, solver=name))


def test_unknown_solver_raises():
    with pytest.raises(KeyError):
        api.get_solver("nope")
    with pytest.raises(ValueError):
        api.SolveConfig(budget=1.0, stop_policy="bogus")


def test_legacy_wrappers_match_registry(tiny_data, tiny_problem):
    """The pre-registry keyword entrypoints are thin shims: same sequence."""
    from repro.core import SOLVERS
    budget = _budget(tiny_data)
    old = SOLVERS["greedy"](tiny_problem, budget)
    new = api.solve(tiny_problem, api.SolveConfig(budget=budget,
                                                  solver="greedy"))
    assert old.order == new.order
    assert old.f_final == new.f_final


def test_solver_equivalence_fixed_seed(tiny_data, tiny_problem):
    """Acceptance: redesigned greedy/lazy/optpes select the same clause
    sequence on a fixed seed (up to exact ties, cf. Thm 4.2)."""
    budget = _budget(tiny_data)
    greedy = api.solve(tiny_problem, api.SolveConfig(budget=budget,
                                                     solver="greedy"))
    lazy = api.solve(tiny_problem, api.SolveConfig(budget=budget,
                                                   solver="lazy"))
    optpes = api.solve(tiny_problem, api.SolveConfig(budget=budget,
                                                     solver="optpes"))
    assert lazy.order == greedy.order
    assert optpes.f_final >= greedy.f_final * 0.999


# -- SolverState + warm starts ------------------------------------------------

def test_solver_state_pytree(tiny_problem):
    import jax
    state = tiny_problem.init_state()
    leaves = jax.tree_util.tree_leaves(state)
    assert len(leaves) == 5
    state2 = jax.jit(lambda s: s)(state)      # passes jit boundary intact
    assert int(state2.step) == 0
    applied = jax.jit(tiny_problem.apply)(state, 0)
    assert int(applied.step) == 1
    assert bool(applied.selected[0])


def test_warm_start_equals_cold_solve(tiny_data, tiny_problem):
    """Acceptance: budget-sweep warm start. Under the truncate stop policy
    the greedy path is budget-independent, so resuming the B1 state to B2
    selects EXACTLY what a cold B2 solve selects."""
    b2 = _budget(tiny_data)
    b1 = b2 / 2
    cold = api.solve(tiny_problem, api.SolveConfig(
        budget=b2, solver="greedy", stop_policy="truncate"))
    part = api.solve(tiny_problem, api.SolveConfig(
        budget=b1, solver="greedy", stop_policy="truncate"))
    resumed = api.solve(tiny_problem, api.SolveConfig(
        budget=b2, solver="greedy", stop_policy="truncate"),
        state=part.state)
    assert part.order == cold.order[:len(part.order)]
    assert part.order + resumed.order == cold.order
    np.testing.assert_array_equal(resumed.selected, cold.selected)
    assert abs(resumed.f_final - cold.f_final) < 1e-6


def test_solve_sweep_matches_cold_solves(tiny_data, tiny_problem):
    b = _budget(tiny_data)
    budgets = [b / 4, b / 2, b]
    sweep = api.solve_sweep(tiny_problem, budgets, api.SolveConfig(
        budget=b, solver="greedy"))
    assert len(sweep) == 3
    for budget, r in zip(budgets, sweep):
        cold = api.solve(tiny_problem, api.SolveConfig(
            budget=budget, solver="greedy", stop_policy="truncate"))
        assert r.order == cold.order
        assert r.g_final <= budget + 1e-6
    # monotone in budget
    assert sweep[0].f_final <= sweep[1].f_final <= sweep[2].f_final


def test_warm_start_lazy_continues_feasibly(tiny_data, tiny_problem):
    """Lazy greedy resumes from a greedy-built state and stays feasible."""
    b2 = _budget(tiny_data)
    part = api.solve(tiny_problem, api.SolveConfig(
        budget=b2 / 2, solver="greedy", stop_policy="truncate"))
    resumed = api.solve(tiny_problem, api.SolveConfig(
        budget=b2, solver="lazy"), state=part.state)
    assert resumed.g_final <= b2 + 1e-6
    assert resumed.f_final >= part.f_final - 1e-9
    assert int(resumed.state.step) == len(part.order) + len(resumed.order)


def test_warm_start_rejected_without_support(tiny_problem):
    state = tiny_problem.init_state()
    with pytest.raises(ValueError):
        api.solve(tiny_problem, api.SolveConfig(budget=10.0, solver="isk1"),
                  state=state)


# -- Trace --------------------------------------------------------------------

def test_time_limit_enforced_with_sparse_recording(tiny_problem, tiny_data):
    """Regression for the th[-1] bug: the wall-clock limit must bind every
    step even when record_every would only refresh the history rarely."""
    r = api.solve(tiny_problem, api.SolveConfig(
        budget=_budget(tiny_data), solver="greedy",
        record_every=10_000, time_limit=0.0))
    # limit of 0s -> at most one selection can slip through
    assert len(r.order) <= 1


def test_trace_hooks_fire(tiny_problem, tiny_data):
    steps, records = [], []
    r = api.solve(tiny_problem, api.SolveConfig(
        budget=_budget(tiny_data), solver="greedy", max_steps=7,
        record_every=3,
        on_step=lambda t: steps.append(t.n_selections),
        on_record=lambda t: records.append(t.last_f)))
    assert len(steps) == len(r.order)
    # one record per 3 selections (+ the forced first one)
    assert len(records) == (len(r.order) + 2) // 3


def test_record_every_thins_history(tiny_problem, tiny_data):
    dense = api.solve(tiny_problem, api.SolveConfig(
        budget=_budget(tiny_data), solver="greedy", max_steps=8))
    sparse = api.solve(tiny_problem, api.SolveConfig(
        budget=_budget(tiny_data), solver="greedy", max_steps=8,
        record_every=4))
    assert len(dense.f_history) == 9          # seed point + 8 selections
    # seed + records at selections 1 and 5 + final flush of selection 8
    assert len(sparse.f_history) == 4
    assert sparse.f_history[-1] == dense.f_history[-1]   # tail is flushed
    assert dense.order == sparse.order        # recording never alters path


# -- TieringPipeline ----------------------------------------------------------

def test_pipeline_end_to_end_smoke():
    pipe = (api.TieringPipeline.from_synthetic(seed=0, scale="tiny")
            .mine(min_support=1e-3)
            .solve("optpes", budget_frac=BUDGET_FRAC))
    assert pipe.result is not None
    cov = pipe.coverage()
    assert 0.0 < cov["train"] <= 1.0
    assert pipe.verify()                      # Theorem 3.1, exhaustively
    engine = pipe.deploy()
    queries = pipe.log.queries[:64]
    out = engine.serve(list(queries))
    ref = engine.serve_reference(list(queries))
    assert all(np.array_equal(a, b) for a, b in zip(out, ref))


def test_pipeline_from_data_and_flow(tiny_data):
    pipe = api.TieringPipeline.from_data(tiny_data)
    pipe.solve("flow-popularity", budget=tiny_data.n_docs // 2)
    assert pipe.result.extra["flow"].tier1_docs.sum() <= tiny_data.n_docs // 2
    # flow picks docs, not clauses: no clause tiering to deploy -> loud error
    with pytest.raises(RuntimeError, match="flow"):
        pipe.tiering()
    with pytest.raises(RuntimeError, match="flow"):
        pipe.deploy()


def test_pipeline_rejects_config_plus_args(tiny_data):
    pipe = api.TieringPipeline.from_data(tiny_data)
    cfg = api.SolveConfig(budget=tiny_data.n_docs // 2, solver="greedy")
    with pytest.raises(ValueError):
        pipe.solve("greedy", budget=10, config=cfg)
    with pytest.raises(ValueError):
        pipe.solve("greedy", config=cfg, max_steps=3)
    pipe.solve(config=cfg)                    # config alone is fine
    assert pipe.result.name == "greedy"


def test_multitier_forwards_config_kwargs(tiny_data):
    """Registry path must route time_limit/max_steps to SolveConfig fields."""
    from repro.core.multitier import build_multitier
    mt = build_multitier(tiny_data, [tiny_data.n_docs // 2],
                         solver="greedy", max_steps=5)
    # max_steps=5 must actually bound the solve (5 clauses -> small tier)
    assert len(mt.tiers[0].clauses) <= 5


def test_pipeline_requires_mine_before_solve():
    pipe = api.TieringPipeline.from_synthetic(seed=0, scale="tiny")
    with pytest.raises(RuntimeError):
        pipe.solve("greedy")


def test_pipeline_sweep(tiny_data):
    pipe = api.TieringPipeline.from_data(tiny_data)
    budgets = [tiny_data.n_docs // 4, tiny_data.n_docs // 2]
    results = pipe.sweep(budgets, "greedy")
    assert len(results) == 2
    assert pipe.result is results[-1]
    assert pipe.verify()
