"""Partitioned-knapsack constraint core.

The load-bearing guarantees:
  * `GlobalBudget` is BIT-IDENTICAL to the pre-refactor inline-budget
    solvers — pinned against an in-test reimplementation of the original
    greedy step (the semantics of record), for the full selection order.
  * A one-partition `PartitionedBudget` equals `GlobalBudget` exactly.
  * Multi-partition caps are hard: every solver's per-shard fill g_k(X)
    respects B_k, and a clause is masked the moment ANY partition it
    touches would overflow — even when the global budget has room.
  * The batched per-partition cost-gain kernel agrees across backends and
    with brute force.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (GlobalBudget, PartitionedBudget, SCSKProblem,
                        SolveConfig, partition_bounds, registry)
from repro.core.greedy import BIG

PARTITION_SOLVERS = ("greedy", "lazy", "optpes", "stochastic")


def _budget(data, frac=0.5) -> float:
    return float(int(data.n_docs * frac))


# -- GlobalBudget bit-identity (regression vs the pre-refactor semantics) ----

def _reference_greedy_order(problem: SCSKProblem, budget: float) -> list[int]:
    """The ORIGINAL inline-budget greedy step, reimplemented verbatim:
    feasible = ~selected & (f>0) & (g_used + gg <= budget); score = f/g with
    the BIG stand-in for free clauses; argmax; stop at first infeasible."""
    state = problem.init_state()
    order = []
    budget = jnp.float32(budget)
    for _ in range(problem.n_clauses):
        fg = problem.f_gains(state.covered_q)
        gg = problem.g_gains(state.covered_d)
        candidates = (~state.selected) & (fg > 0.0)
        feasible = candidates & (state.g_used + gg <= budget)
        score = jnp.where(gg <= 0.0, fg * BIG, fg / jnp.maximum(gg, 1e-30))
        score = jnp.where(feasible, score, -jnp.inf)
        j = int(jnp.argmax(score))
        if not bool(feasible[j]):
            break
        state = problem.apply(state, jnp.int32(j))
        order.append(j)
    return order


def test_global_budget_bit_identical_to_pre_refactor_greedy(tiny_data,
                                                            tiny_problem):
    b = _budget(tiny_data)
    want = _reference_greedy_order(tiny_problem, b)
    got = registry.solve(tiny_problem, SolveConfig(budget=b, solver="greedy"))
    assert got.order == want


@pytest.mark.parametrize("solver", PARTITION_SOLVERS)
def test_single_partition_equals_global(tiny_data, tiny_problem, solver):
    """P=1 partitioned caps reduce to the scalar knapsack, selection-exact."""
    b = _budget(tiny_data)
    r_global = registry.solve(tiny_problem,
                              SolveConfig(budget=b, solver=solver, seed=3))
    r_one = registry.solve(tiny_problem,
                           SolveConfig(budget=b, solver=solver, seed=3,
                                       budget_split=[b]))
    assert r_one.order == r_global.order
    np.testing.assert_array_equal(r_one.selected, r_global.selected)


def test_explicit_global_constraint_equals_budget(tiny_data, tiny_problem):
    b = _budget(tiny_data)
    r1 = registry.solve(tiny_problem, SolveConfig(budget=b, solver="greedy"))
    r2 = registry.solve(tiny_problem, SolveConfig(
        budget=b, solver="greedy", constraint=GlobalBudget(budget=b)))
    assert r1.order == r2.order


# -- per-partition caps are hard ---------------------------------------------

@pytest.mark.parametrize("solver", PARTITION_SOLVERS)
def test_partitioned_caps_respected(tiny_data, tiny_problem, solver):
    b = _budget(tiny_data)
    split = {0: 0.7 * b, 1: 0.3 * b}
    r = registry.solve(tiny_problem, SolveConfig(
        budget=b, solver=solver, budget_split=split))
    caps = r.extra["caps"]
    assert np.all(r.extra["g_part"] <= caps + 1e-6)
    assert r.g_final <= caps.sum() + 1e-6
    # the fill report is consistent with the final covered bitset
    bounds = r.extra["bounds"]
    cd = np.asarray(r.state.covered_d)
    for k, (lo, hi) in enumerate(zip(bounds, bounds[1:])):
        assert r.extra["g_part"][k] == np.bitwise_count(cd[lo:hi]).sum()


def test_partition_masks_clause_global_budget_would_admit():
    """A clause whose docs all land in a FULL partition must be skipped even
    though the global budget still has room — the structural difference
    between one knapsack and per-shard caps."""
    # 2 partitions x 1 word. clause 0: 8 docs in part 0; clause 1: 8 docs in
    # part 1; clause 2: 8 MORE docs in part 0. Caps [16, 16]... then cap
    # part 0 at 8: greedy takes clause 0 (or 2), then must skip the other
    # part-0 clause and take clause 1, despite 24 <= 32 globally.
    cq = np.zeros((3, 1), np.uint32)
    cq[0, 0] = 0b0001            # each clause covers a distinct query
    cq[1, 0] = 0b0010
    cq[2, 0] = 0b0100
    cd = np.zeros((3, 2), np.uint32)
    cd[0, 0] = 0x000000FF        # 8 docs, partition 0
    cd[1, 1] = 0x000000FF        # 8 docs, partition 1
    cd[2, 0] = 0x0000FF00        # 8 different docs, partition 0
    w = np.zeros(32, np.float32)
    w[:3] = [0.5, 0.3, 0.4]      # clause 0 best, then 2, then 1
    problem = SCSKProblem(
        clause_query_bits=jnp.asarray(cq), clause_doc_bits=jnp.asarray(cd),
        query_weights=jnp.asarray(w), test_weights=jnp.asarray(w),
        n_queries=3, n_docs=64)
    r_global = registry.solve(problem, SolveConfig(budget=24.0,
                                                   solver="greedy"))
    assert set(r_global.order) == {0, 1, 2}  # global: everything fits in 24
    r_split = registry.solve(problem, SolveConfig(
        budget=24.0, solver="greedy", budget_split=[8.0, 16.0]))
    assert r_split.order == [0, 1]           # part 0 full after clause 0
    np.testing.assert_array_equal(np.asarray(r_split.extra["g_part"]),
                                  [8.0, 8.0])


def test_unsupported_solver_rejects_budget_split(tiny_data, tiny_problem):
    with pytest.raises(ValueError, match="partitioned"):
        registry.solve(tiny_problem, SolveConfig(
            budget=100.0, solver="isk1", budget_split=[50.0, 50.0]))


def test_registry_rejects_unresolved_traffic_split(tiny_problem):
    with pytest.raises(ValueError, match="traffic"):
        registry.solve(tiny_problem, SolveConfig(
            budget=100.0, solver="greedy", budget_split="traffic"))


# -- partitioned sweeps -------------------------------------------------------

def test_partitioned_sweep_matches_cold_solves(tiny_data, tiny_problem):
    """Warm-started split sweeps equal cold truncate solves per point: the
    truncate ranking never reads the caps, so the path is budget-free."""
    b = _budget(tiny_data)
    budgets = [b / 2, b]
    base = PartitionedBudget.from_split(tiny_problem.n_docs,
                                        [0.6 * b, 0.4 * b])
    cfg = SolveConfig(budget=b, solver="greedy", constraint=base)
    warm = registry.solve_sweep(tiny_problem, budgets, cfg)
    for bb, r in zip(budgets, warm):
        cold = registry.solve(tiny_problem, cfg.replace(
            budget=float(bb), stop_policy="truncate",
            constraint=base.scaled(float(bb))))
        assert r.order == cold.order
        np.testing.assert_array_equal(r.selected, cold.selected)
        assert np.all(r.extra["g_part"] <= base.scaled(bb).caps + 1e-6)


# -- the batched per-partition cost-gain kernel ------------------------------

@pytest.mark.parametrize("c,w,parts", [(37, 11, 3), (5, 3, 1), (130, 33, 5),
                                       (64, 8, 8)])
def test_partition_gain_backends_agree(rng, c, w, parts):
    from repro.kernels import ops
    bounds = partition_bounds(w * 32, parts)
    a = rng.integers(0, 2 ** 32, size=(c, w), dtype=np.uint32)
    m = rng.integers(0, 2 ** 32, size=(w,), dtype=np.uint32)
    want = np.stack(
        [np.bitwise_count(a[:, lo:hi] & ~m[lo:hi]).sum(1, dtype=np.int64)
         for lo, hi in zip(bounds, bounds[1:])], -1)
    for backend in ("xla", "interpret"):
        got = np.asarray(ops.partition_gain(
            jnp.asarray(a), jnp.asarray(m), bounds, backend=backend))
        np.testing.assert_array_equal(got, want, err_msg=backend)
    # totals equal the scalar coverage-gain oracle
    cg = np.asarray(ops.coverage_gain(jnp.asarray(a), jnp.asarray(m)))
    np.testing.assert_array_equal(want.sum(-1), cg)


def test_problem_g_value_per_partition(tiny_problem, rng):
    bounds = partition_bounds(tiny_problem.n_docs, 3)
    cd = rng.integers(0, 2 ** 32, size=(tiny_problem.wd,), dtype=np.uint32)
    got = np.asarray(tiny_problem.g_value(jnp.asarray(cd), bounds=bounds))
    assert got.sum() == float(tiny_problem.g_value(jnp.asarray(cd)))
    for k, (lo, hi) in enumerate(zip(bounds, bounds[1:])):
        assert got[k] == np.bitwise_count(cd[lo:hi]).sum()


# -- bounds + allocator ------------------------------------------------------

def test_partition_bounds_matches_plan_shards():
    from repro.cluster import plan_shards
    for n_docs, p in [(200, 2), (200, 4), (33, 4), (1, 3), (4096, 7)]:
        bounds = partition_bounds(n_docs, p)
        shards = plan_shards(n_docs, p)
        assert bounds[0] == 0
        assert len(bounds) - 1 == len(shards)
        for s, (lo, hi) in zip(shards, zip(bounds, bounds[1:])):
            assert (s.word_lo, s.word_hi) == (lo, hi)


def test_partition_budgets_allocator():
    from repro.api import partition_budgets
    caps = partition_budgets([100, 100, 100], [0.5, 0.3, 0.2], 90)
    assert sum(caps.values()) == 90
    assert caps[0] >= caps[1] >= caps[2]           # monotone in share
    # capacity clamp + redistribution: shard 0 can only hold 10
    caps = partition_budgets([10, 100, 100], [0.9, 0.05, 0.05], 90)
    assert caps[0] == 10 and sum(caps.values()) == 90
    assert all(caps[k] <= c for k, c in enumerate([10, 100, 100]))
    # zero-share shards still absorb overflow rather than losing budget
    caps = partition_budgets([10, 10, 100], [1.0, 0.0, 0.0], 60)
    assert sum(caps.values()) == 60 and caps[0] == 10
    with pytest.raises(ValueError, match="capacity"):
        partition_budgets([10, 10], [0.5, 0.5], 50)


def test_shard_traffic_shares(tiny_data):
    from repro.api import shard_traffic_shares
    bounds = partition_bounds(tiny_data.n_docs, 2)
    w = np.asarray(tiny_data.log.train_weights, np.float64)
    shares = shard_traffic_shares(tiny_data.query_doc_bits, w, bounds)
    assert shares.shape == (2,)
    assert abs(shares.sum() - 1.0) < 1e-12
    assert np.all(shares >= 0)
    # moving all weight onto queries matching only shard-0 docs must tilt
    # the share toward shard 0
    mass0 = np.bitwise_count(
        tiny_data.query_doc_bits[:, :bounds[1]]).sum(1, dtype=np.int64)
    mass1 = np.bitwise_count(
        tiny_data.query_doc_bits[:, bounds[1]:]).sum(1, dtype=np.int64)
    only0 = (mass0 > 0) & (mass1 == 0)
    if only0.any():
        w2 = np.where(only0, 1.0, 0.0)
        shares2 = shard_traffic_shares(tiny_data.query_doc_bits, w2, bounds)
        assert shares2[0] == pytest.approx(1.0)


# -- warm refits across re-allocated caps ------------------------------------

def test_warm_refit_respects_shrunk_caps(tiny_data):
    """Re-allocating caps can hand a shard LESS budget than the warm
    prefix's frozen fill already occupies; the refit must shed the overflow
    so the post-solve fills respect the NEW caps."""
    from repro import api
    b = float(tiny_data.n_docs // 2)
    pipe = api.TieringPipeline.from_data(tiny_data).solve(
        "greedy", budget_split={0: 0.8 * b, 1: 0.2 * b})
    prev = pipe.result
    assert prev.extra["g_part"][0] > 0.3 * b      # shard 0 well-filled
    # warm refit onto INVERTED caps: shard 0 shrinks below its fill
    w = np.asarray(tiny_data.log.train_weights, np.float64)
    pipe.refit(w, state=prev.state,
               budget_split={0: 0.2 * b, 1: 0.8 * b})
    caps = pipe.result.extra["caps"]
    np.testing.assert_array_equal(caps, [0.2 * b, 0.8 * b])
    assert np.all(pipe.result.extra["g_part"] <= caps + 1e-6)


def test_trim_state_sheds_only_overflowing_partitions(tiny_data,
                                                      tiny_problem):
    from repro.core import trim_state
    b = float(tiny_data.n_docs // 2)
    r = registry.solve(tiny_problem, SolveConfig(
        budget=b, solver="greedy", budget_split=[0.8 * b, 0.2 * b]))
    fills = r.extra["g_part"]
    # shrink partition 0's cap below its fill; partition 1 keeps headroom
    tight = PartitionedBudget.from_split(
        tiny_problem.n_docs, [max(1.0, fills[0] // 2), 0.8 * b])
    state, dropped = trim_state(tiny_problem, r.state, tight)
    assert len(dropped) > 0
    new_fills = tight.np_value(np.asarray(state.covered_d))
    assert np.all(new_fills <= np.asarray(tight.caps) + 1e-6)
    # a fitting constraint is a no-op (same object back)
    loose = PartitionedBudget.from_split(tiny_problem.n_docs,
                                         [fills[0] + 1, fills[1] + 1])
    same, none_dropped = trim_state(tiny_problem, r.state, loose)
    assert same is r.state and len(none_dropped) == 0


def test_refit_carries_explicit_constraint(tiny_data):
    """A solve under an explicit PartitionedBudget (no budget_split spec)
    must stay partitioned across refits, not silently degrade to global."""
    from repro import api
    b = float(tiny_data.n_docs // 2)
    constraint = PartitionedBudget.from_split(tiny_data.n_docs,
                                              [0.6 * b, 0.4 * b])
    pipe = api.TieringPipeline.from_data(tiny_data)
    pipe.solve(config=api.SolveConfig(budget=b, solver="greedy",
                                      constraint=constraint))
    w = np.asarray(tiny_data.log.train_weights, np.float64)
    pipe.refit(w, state=None)
    assert pipe.config.constraint is constraint
    assert np.all(pipe.result.extra["g_part"] <=
                  np.asarray(constraint.caps) + 1e-6)
    # budget change rescales the carried caps, same shares
    pipe.refit(w, state=None, budget=b / 2)
    np.testing.assert_allclose(np.asarray(pipe.config.constraint.caps),
                               np.asarray(constraint.caps) / 2)


def test_explicit_caps_conflicting_budget_raises(tiny_data):
    from repro import api
    pipe = api.TieringPipeline.from_data(tiny_data)
    with pytest.raises(ValueError, match="pass one or the other"):
        pipe.solve("greedy", budget=30.0, budget_split={0: 60.0, 1: 40.0})
    # agreeing budget is fine
    pipe.solve("greedy", budget=100.0, budget_split={0: 60.0, 1: 40.0})
    assert pipe.result is not None


# -- pipeline surface --------------------------------------------------------

def test_pipeline_traffic_split_solve_and_refit(tiny_data):
    from repro import api
    pipe = api.TieringPipeline.from_data(tiny_data).solve(
        "greedy", budget_frac=0.5, budget_split="traffic", n_shards=2)
    caps = pipe.result.extra["caps"]
    assert caps.sum() == float(int(tiny_data.n_docs * 0.5))
    assert np.all(pipe.result.extra["g_part"] <= caps + 1e-6)
    assert pipe.n_partitions == 2
    assert pipe.verify()
    # refit against shifted weights re-allocates the caps (same total)
    w = np.asarray(tiny_data.log.train_weights, np.float64)[::-1].copy()
    pipe.refit(w, state=None)
    caps2 = pipe.result.extra["caps"]
    assert caps2.sum() == caps.sum()
    assert pipe.verify()
    # explicitly dropping back to a global budget works
    pipe.refit(w, state=None, budget_split=None)
    assert "caps" not in pipe.result.extra


# -- admission at the cap boundary (repro.ingest's feasibility gate) ----------

def test_partitioned_admission_fills_shard_to_exact_cap():
    """A clause that fills a partition to EXACTLY B_k must be admitted
    (feasibility is <=, not <), the partition must then mask every further
    clause touching it, and docs straddling the word-aligned boundary must
    bill to the right partition — the calls are exactly the ones
    `ingest.IngestController._admit` makes."""
    # 2 partitions x 1 word; docs 24..31 sit at the TOP of word 0 (adjacent
    # to the boundary), doc 32 is bit 0 of word 1 (just past it)
    cq = np.zeros((3, 1), np.uint32)
    cq[0, 0] = 0b0001
    cq[1, 0] = 0b0010
    cq[2, 0] = 0b0100
    cd = np.zeros((3, 2), np.uint32)
    cd[0, 0] = np.uint32(0xFF000000)   # 8 docs at word-0's top: partition 0
    cd[1, 1] = np.uint32(0x00000001)   # doc 32, first past the boundary
    cd[2, 0] = np.uint32(0x00000001)   # one more partition-0 doc
    w = np.zeros(32, np.float32)
    w[:3] = [0.5, 0.3, 0.4]
    problem = SCSKProblem(
        clause_query_bits=jnp.asarray(cq), clause_doc_bits=jnp.asarray(cd),
        query_weights=jnp.asarray(w), test_weights=jnp.asarray(w),
        n_queries=3, n_docs=64)
    constraint = PartitionedBudget(caps=jnp.asarray([8.0, 4.0]),
                                   bounds=(0, 1, 2))
    state = problem.init_state()

    def offer(j):
        rows = problem.clause_doc_bits[j:j + 1]
        _, g_part = constraint.gains(problem, state.covered_d, rows=rows)
        used = constraint.used(problem, state)
        return bool(np.asarray(constraint.feasible(used, g_part))[0]), g_part

    # boundary docs bill to partition 0 only
    ok, g_part = offer(0)
    np.testing.assert_array_equal(np.asarray(g_part)[0], [8.0, 0.0])
    assert ok                           # fills partition 0 to exactly B_0
    state = problem.apply(state, 0)
    np.testing.assert_array_equal(
        np.asarray(constraint.used(problem, state)), [8.0, 0.0])
    np.testing.assert_array_equal(
        constraint.np_value(np.asarray(state.covered_d)), [8.0, 0.0])

    ok2, g2 = offer(2)                  # ANY partition-0 doc now overflows
    np.testing.assert_array_equal(np.asarray(g2)[0], [1.0, 0.0])
    assert not ok2
    ok1, g1 = offer(1)                  # the doc just PAST the boundary fits
    np.testing.assert_array_equal(np.asarray(g1)[0], [0.0, 1.0])
    assert ok1
    state = problem.apply(state, 1)
    np.testing.assert_array_equal(
        np.asarray(constraint.used(problem, state)), [8.0, 1.0])
