"""End-to-end system test: data -> mining -> SCSK solve -> tiering -> serving.

This is the full paper pipeline at 'tiny' scale, asserting the headline
behaviours: correctness (Thm 3.1), budget feasibility, generalization to
novel queries, and serving-cost savings.
"""
import numpy as np

from repro.core import SOLVERS, SCSKProblem
from repro.core.tiering import ClauseTiering
from repro.data import incidence, synthetic
from repro.serve.engine import TieredEngine


def test_end_to_end_pipeline():
    corpus, log = synthetic.make_tiering_dataset(7, "tiny")
    assert log.novel_test_mass() > 0.0      # test traffic has unseen queries

    data = incidence.build_tiering_data(corpus, log, min_support=2e-3)
    assert len(data.clauses) > 10

    problem = SCSKProblem.from_data(data)
    budget = corpus.n_docs // 2
    result = SOLVERS["optpes"](problem, budget)
    assert result.g_final <= budget

    tiering = ClauseTiering.from_selection(data, result.selected)
    assert tiering.verify_correctness(data)
    cov = tiering.coverage(data)
    assert cov["train"] > 0.3               # tier 1 worth building
    assert cov["test"] > 0.3                # ... and it generalizes
    assert cov["tier1_frac"] <= 0.5 + 1e-9

    engine = TieredEngine(data.postings, tiering, data.n_docs)
    queries = [log.queries[i] for i in range(128)]
    got = engine.serve(queries)
    want = engine.serve_reference(queries)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a, b)
    assert engine.stats.n_tier1 > 0
