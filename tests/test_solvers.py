"""Solver behaviour: feasibility, equivalence, quality orderings (paper §5.1)."""
import itertools

import numpy as np
import pytest

from repro.core import SOLVERS, SCSKProblem, bitset

BUDGET_FRAC = 0.5


@pytest.fixture(scope="module")
def solved(tiny_problem):
    budget = tiny_problem.n_docs * BUDGET_FRAC
    return {name: SOLVERS[name](tiny_problem, budget) for name in SOLVERS}, budget


def _true_fg(problem, selected):
    import jax.numpy as jnp
    idx = np.nonzero(selected)[0]
    if len(idx) == 0:
        return 0.0, 0.0
    cq = bitset.or_rows(problem.clause_query_bits[jnp.asarray(idx)], 0)
    cd = bitset.or_rows(problem.clause_doc_bits[jnp.asarray(idx)], 0)
    return float(problem.f_value(cq)), float(problem.g_value(cd))


def test_all_solvers_feasible(solved, tiny_problem):
    results, budget = solved
    for name, r in results.items():
        f_true, g_true = _true_fg(tiny_problem, r.selected)
        assert g_true <= budget + 1e-6, name
        assert abs(g_true - r.g_final) < 1e-4, name
        assert abs(f_true - r.f_final) < 1e-4, name


def test_lazy_equals_dense_greedy(solved):
    results, _ = solved
    assert results["lazy"].order == results["greedy"].order
    assert abs(results["lazy"].f_final - results["greedy"].f_final) < 1e-6


def test_optpes_matches_greedy_value(solved):
    """Thm 4.2: Opt/Pes performs exact greedy selections (order may differ
    only on exact ratio ties), so the objective must match closely."""
    results, _ = solved
    assert results["optpes"].f_final >= results["greedy"].f_final * 0.999


def test_lazy_uses_fewer_evaluations(solved, tiny_problem):
    results, _ = solved
    assert results["lazy"].n_exact_evals < results["greedy"].n_exact_evals


def test_greedy_beats_agnostic(solved):
    """Paper §5.1: constraint-agnostic converges clearly suboptimal."""
    results, _ = solved
    assert results["greedy"].f_final > results["agnostic"].f_final


def test_greedy_competitive_with_isk(solved):
    """Paper §5.1: greedy's final objective ≥ ISK1's; ISK2 close to greedy."""
    results, _ = solved
    assert results["greedy"].f_final >= results["isk1"].f_final - 1e-9
    assert results["isk2"].f_final >= results["greedy"].f_final * 0.95


def test_isk_histories_monotone_feasible(solved, tiny_problem):
    results, budget = solved
    for name in ("isk1", "isk2"):
        r = results[name]
        assert np.all(r.g_history <= budget + 1e-6)


def test_greedy_near_bruteforce_on_micro(tiny_problem):
    """On a micro instance (first 10 clauses), compare to exhaustive opt."""
    problem = tiny_problem
    import jax.numpy as jnp
    c = min(10, problem.n_clauses)
    sub = SCSKProblem(
        clause_query_bits=problem.clause_query_bits[:c],
        clause_doc_bits=problem.clause_doc_bits[:c],
        query_weights=problem.query_weights,
        test_weights=problem.test_weights,
        n_queries=problem.n_queries, n_docs=problem.n_docs)
    budget = problem.n_docs * 0.25
    best = 0.0
    for r in range(1, c + 1):
        for combo in itertools.combinations(range(c), r):
            sel = np.zeros(c, bool)
            sel[list(combo)] = True
            f, g = _true_fg(sub, sel)
            if g <= budget:
                best = max(best, f)
    got = SOLVERS["greedy"](sub, budget)
    # greedy for SCSK carries bicriteria guarantees; in practice it is
    # near-optimal — assert a generous floor plus feasibility.
    assert got.f_final >= 0.6 * best
    assert got.g_final <= budget


def test_solution_path_monotone(solved):
    results, _ = solved
    r = results["greedy"]
    assert np.all(np.diff(r.f_history) >= -1e-9)
    assert np.all(np.diff(r.g_history) >= -1e-9)


def test_sparse_step_matches_dense_greedy(tiny_data, tiny_problem):
    """The production sparse round selects the same clause as dense greedy."""
    import jax.numpy as jnp
    from repro.core.greedy import greedy_step
    from repro.core.sparse_step import sparse_greedy_step
    from repro.data import incidence

    ids = incidence.padded_id_lists(tiny_data.clause_doc_bits,
                                    tiny_data.n_docs)
    problem = tiny_problem
    state = problem.init_state()
    budget = jnp.float32(tiny_data.n_docs // 2)
    ids_j = jnp.asarray(ids)
    sq, sd = problem.empty_state()
    ssel = jnp.zeros(problem.n_clauses, bool)
    sg = jnp.float32(0.0)
    for _ in range(5):
        state, f_val, j_d, stop_d = greedy_step(problem, state, budget)
        sq, sd, ssel, sg, j_s, stop_s = sparse_greedy_step(
            ids_j, problem.clause_query_bits, problem.query_weights,
            sq, sd, ssel, sg, budget)
        assert int(j_d) == int(j_s)
        assert bool(stop_d) == bool(stop_s)
    import numpy as np
    np.testing.assert_array_equal(np.asarray(state.covered_d), np.asarray(sd))
