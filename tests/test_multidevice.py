"""Multi-device semantics, run in a subprocess with 8 fake CPU devices
(the main test process must keep seeing 1 device).

Verifies: MoE expert-parallel == oracle on a real 2x4 mesh; row-sharded
embedding lookup == plain gather; quantized psum ~= exact psum; EGNN
edge-sharded message passing == single-device result; a reduced dry-run
cell lowers+compiles on the 8-device mesh.
"""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.distributed import mesh_context
from repro.models import moe as M, embedding, egnn as G

mesh = jax.make_mesh((2, 4), ("data", "model"))
assert len(jax.devices()) == 8

# --- MoE EP on a real mesh vs oracle
cfg = M.MoEConfig(n_experts=8, top_k=2, d_expert=16, capacity_factor=4.0)
params = M.init_moe_params(jax.random.key(0), 8, cfg)
x = jax.random.normal(jax.random.key(1), (16, 8))
with mesh, mesh_context.use_mesh(mesh):
    y_ep, aux = jax.jit(lambda p, x: M.moe_apply(p, x, cfg))(params, x)
y_oracle = M.moe_apply_dense_oracle(params, x, cfg)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_oracle),
                           rtol=1e-5, atol=1e-5)
print("moe-ep-8dev OK")

# --- row-sharded embedding lookup
table = jax.random.normal(jax.random.key(2), (64, 4))
idx = jax.random.randint(jax.random.key(3), (16, 3), 0, 64)
with mesh, mesh_context.use_mesh(mesh):
    got = jax.jit(embedding.lookup)(table, idx)
np.testing.assert_allclose(np.asarray(got), np.asarray(table[idx]),
                           rtol=1e-6)
print("embedding-psum-8dev OK")

# --- quantized psum across 8 data shards
from repro.distributed.compression import quantized_psum
from repro.models.moe import shard_map
mesh1 = jax.make_mesh((8,), ("data",))
v = jax.random.normal(jax.random.key(4), (8, 32))
exact = v.sum(axis=0)
got = shard_map(lambda s: quantized_psum(s[0], "data"), mesh1,
                in_specs=(P("data"),), out_specs=P())(v)
err = float(jnp.abs(got - exact).max())
assert err < 8 * 2 * float(jnp.abs(v).max()) / 127, err
print("quantized-psum-8dev OK err=%.2e" % err)

# --- EGNN edge-sharded vs single-device
gcfg = G.EGNNConfig(n_layers=2, d_hidden=8, d_feat=4, n_classes=2)
gparams = G.init_params(jax.random.key(5), gcfg)
rng = np.random.default_rng(0)
batch = {
    "node_feat": jnp.asarray(rng.standard_normal((20, 4)), jnp.float32),
    "coords": jnp.asarray(rng.standard_normal((20, 3)), jnp.float32),
    "edges": jnp.asarray(rng.integers(0, 20, (2, 64)), jnp.int32),
}
h_ref, x_ref = G.forward(gparams, batch, gcfg)        # no mesh: local path
with mesh, mesh_context.use_mesh(mesh):
    h_sh, x_sh = jax.jit(lambda p, b: G.forward(p, b, gcfg))(gparams, batch)
np.testing.assert_allclose(np.asarray(h_ref), np.asarray(h_sh),
                           rtol=1e-4, atol=1e-5)
print("egnn-edge-shard-8dev OK")

# --- reduced dry-run lowering on the 8-device mesh
from repro.configs import registry as R
from repro.distributed import sharding
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import make_train_step
arch = R.get_arch("gemma2-2b")
scfg, sbatch, _ = arch.smoke()
init_state, train_step = make_train_step(
    arch.loss_fn(scfg), OptimizerConfig(name="adamw"))
aparams = jax.eval_shape(lambda: __import__("repro.models.transformer",
    fromlist=["x"]).init_params(jax.random.key(0), scfg))
astate = jax.eval_shape(init_state, aparams)
pspecs = sharding.add_fsdp(arch.param_specs(scfg), aparams, mesh,
                           min_size=64)
state_sh = sharding.state_shardings(mesh, pspecs, astate)
import jax.numpy as jnp2
batch_sds = {k: jax.ShapeDtypeStruct((16, 32), jnp2.int32)
             for k in ("tokens", "labels")}
batch_sh = {k: NamedSharding(mesh, P("data", None)) for k in batch_sds}
with mesh, mesh_context.use_mesh(mesh):
    compiled = jax.jit(train_step, in_shardings=(state_sh, batch_sh)) \
        .lower(astate, batch_sds).compile()
assert compiled.memory_analysis() is not None
print("dryrun-8dev OK")
print("ALL-MULTIDEVICE-OK")
"""


def test_multidevice_semantics():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"}, cwd=".", timeout=900)
    assert "ALL-MULTIDEVICE-OK" in out.stdout, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
