"""Flow-family baselines (paper §2.3/§5.2) behave as the paper describes."""
import numpy as np

from repro.core import bitset, flow


def test_popularity_and_flowmax_feasible(tiny_data):
    budget = tiny_data.n_docs // 2
    for fn in (flow.popularity, flow.flow_max):
        r = fn(tiny_data, budget)
        assert r.tier1_docs.sum() <= budget
        assert 0.0 <= r.train_coverage <= 1.0
        # correctness by construction: eligible queries fit in tier 1
        t1 = bitset.np_pack(r.tier1_docs)
        bad = np.any(tiny_data.query_doc_bits[r.eligible_queries] & ~t1[None, :])
        assert not bad


def test_flow_sgd_improves_over_random(tiny_data):
    budget = tiny_data.n_docs // 2
    r = flow.flow_sgd(tiny_data, budget, steps=120, batch=128, seed=0)
    assert r.tier1_docs.sum() <= budget
    # random tier-1 baseline
    rng = np.random.default_rng(0)
    rand_docs = np.zeros(tiny_data.n_docs, bool)
    rand_docs[rng.choice(tiny_data.n_docs, budget, replace=False)] = True
    t1 = bitset.np_pack(rand_docs)
    contained = ~np.any(tiny_data.query_doc_bits & ~t1[None, :], axis=1)
    rand_cov = tiny_data.log.train_weights[
        contained & (tiny_data.log.train_weights > 0)].sum()
    assert r.train_coverage > rand_cov


def test_flow_cannot_cover_novel_queries(tiny_data):
    """The structural limitation the paper fixes: ψ^flow routes every
    unseen query to Tier 2."""
    budget = tiny_data.n_docs // 2
    r = flow.flow_sgd(tiny_data, budget, steps=60, batch=128, seed=0)
    novel = tiny_data.log.train_weights == 0
    assert not np.any(r.eligible_queries & novel)


def test_clause_covers_novel_queries(tiny_data, tiny_problem):
    """And the clause method does cover some never-seen-in-train queries."""
    from repro.core import SOLVERS
    from repro.core.tiering import ClauseTiering
    r = SOLVERS["optpes"](tiny_problem, tiny_data.n_docs // 2)
    tiering = ClauseTiering.from_selection(tiny_data, r.selected)
    elig = tiering.classify_queries(tiny_data.log.query_bits)
    novel = tiny_data.log.train_weights == 0
    if novel.sum() == 0:  # dataset quirk guard
        return
    assert np.any(elig & novel)
